"""Train state + train step (used by the train_4k dry-run shape and the
training example)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.params import abstract_params, init_params

from .optimizer import AdamWState, adamw_init, adamw_update, cosine_lr

AUX_LOSS_COEF = 0.01


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def make_train_state(cfg: ModelConfig, key) -> TrainState:
    params = init_params(T.model_decls(cfg), key)
    return TrainState(params, adamw_init(params))


def abstract_train_state(cfg: ModelConfig) -> TrainState:
    """ShapeDtypeStruct train state for dry-run lowering."""
    params = abstract_params(T.model_decls(cfg))
    zeros = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                         params)
    opt = AdamWState(jax.ShapeDtypeStruct((), jnp.int32), zeros, zeros)
    return TrainState(params, opt)


def loss_fn(params, cfg: ModelConfig, batch, *, remat=False):
    kwargs = {}
    for k in ("mm_embeds", "positions", "enc_frames"):
        if k in batch:
            kwargs[k] = batch[k]
    logits, _, aux = T.forward(params, cfg, batch["tokens"], remat=remat,
                               **kwargs)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask", jnp.ones_like(labels, jnp.float32))
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + AUX_LOSS_COEF * aux, (loss, aux)


def train_step(state: TrainState, batch, cfg: ModelConfig, *, base_lr=3e-4,
               remat=False):
    (_, (loss, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        state.params, cfg, batch, remat=remat)
    lr = cosine_lr(state.opt.step + 1, base_lr=base_lr)
    new_params, new_opt, gnorm = adamw_update(grads, state.opt, state.params,
                                              lr=lr)
    metrics = {"loss": loss, "aux_loss": aux, "grad_norm": gnorm, "lr": lr}
    return TrainState(new_params, new_opt), metrics
