"""Synthetic packed-token data pipeline.

Generates document streams with a Zipfian token distribution and packs them
into fixed-length training sequences with cross-document attention reset
omitted (standard packing). Deterministic per (seed, step) so multi-host
shards stay consistent without communication.
"""
from __future__ import annotations

import numpy as np


class PackedTokenDataset:
    def __init__(self, vocab_size: int, seq_len: int, *, seed: int = 0,
                 mean_doc_len: int = 512, zipf_a: float = 1.2):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.seed = seed
        self.mean_doc_len = mean_doc_len
        self.zipf_a = zipf_a

    def _doc(self, rng: np.random.Generator) -> np.ndarray:
        n = max(8, int(rng.exponential(self.mean_doc_len)))
        toks = rng.zipf(self.zipf_a, size=n)
        return np.clip(toks, 1, self.vocab_size - 1).astype(np.int32)

    def batch(self, step: int, batch_size: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        rows = []
        for _ in range(batch_size):
            buf: list[np.ndarray] = []
            total = 0
            while total < self.seq_len + 1:
                d = self._doc(rng)
                buf.append(d)
                total += len(d)
            row = np.concatenate(buf)[: self.seq_len + 1]
            rows.append(row)
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}
