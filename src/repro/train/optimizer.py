"""AdamW in pure JAX (optax is not available in this environment).

Moments are f32 regardless of param dtype (mixed-precision master states).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def adamw_update(grads, state: AdamWState, params, *, lr=3e-4, b1=0.9,
                 b2=0.95, eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    """Returns (new_params, new_state)."""
    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), gnorm


def cosine_lr(step, *, base_lr=3e-4, warmup=100, total=10000, min_frac=0.1):
    t = step.astype(jnp.float32)
    warm = t / jnp.maximum(warmup, 1)
    prog = jnp.clip((t - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.where(t < warmup, warm, cos)
