"""Checkpointing via numpy .npz (orbax unavailable offline).

Flattens the train-state pytree with '/'-joined key paths; restores into the
same treedef. Works for params-only saves too (serving weights).
"""
from __future__ import annotations

import os

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def save(path: str, tree) -> None:
    flat = _flatten(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp.npz"
    # bf16 is not a native numpy dtype: view as uint16 with a name marker
    store = {}
    for k, v in flat.items():
        if v.dtype.name == "bfloat16":
            store["BF16::" + k] = v.view(np.uint16)
        else:
            store[k] = v
    np.savez(tmp, **store)
    os.replace(tmp, path)


def load(path: str, like):
    """Restore into the structure of `like` (same treedef)."""
    import jax.numpy as jnp
    data = np.load(path)
    flat = {}
    for k in data.files:
        if k.startswith("BF16::"):
            flat[k[6:]] = data[k].view(jnp.bfloat16.dtype)
        else:
            flat[k] = data[k]
    ref = _flatten(like)
    assert set(ref) == set(flat), (
        f"checkpoint keys mismatch: missing={set(ref)-set(flat)} "
        f"extra={set(flat)-set(ref)}")
    leaves_like, treedef = jax.tree.flatten(like)
    # rebuild in like's flatten order
    names = list(_flatten(like).keys())
    assert len(names) == len(leaves_like)
    return treedef.unflatten([jnp.asarray(flat[n]) for n in names])
