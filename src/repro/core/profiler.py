"""Workload Profiler (paper §3.2).

Offline component: executes a representative per-modality workload against
the target model ONE REQUEST AT A TIME (no contention) and records
preprocess / encode / prefill times plus KV token counts. The resulting
profile trains the Impact Estimator and the Request Classifier.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.serving.request import Request


@dataclass
class ProfileRecord:
    modality: str
    text_tokens: int
    mm_units: int
    prompt_tokens: int      # KV footprint of the prompt (tokens)
    preprocess_time: float
    encode_time: float
    prefill_time: float

    @property
    def ttft(self) -> float:
        return self.preprocess_time + self.encode_time + self.prefill_time


@dataclass
class Profile:
    model: str
    records: list[ProfileRecord] = field(default_factory=list)

    def by_modality(self, modality: str) -> list[ProfileRecord]:
        return [r for r in self.records if r.modality == modality]

    def features(self, modality: str):
        """(X, prefill_times, prompt_tokens) arrays for estimator training."""
        rs = self.by_modality(modality)
        X = np.array([[r.text_tokens, r.mm_units] for r in rs], np.float64)
        t = np.array([r.prefill_time for r in rs], np.float64)
        kv = np.array([r.prompt_tokens for r in rs], np.float64)
        return X, t, kv


class WorkloadProfiler:
    """Runs isolated requests through an executor and collects a Profile.

    `executor` must expose ``isolated_run(request) -> ProfileRecord`` — both
    the real JAX executor and the calibrated simulation executor do.
    """

    def __init__(self, executor, model_name: str):
        self.executor = executor
        self.model_name = model_name

    def build(self, requests: list[Request]) -> Profile:
        profile = Profile(self.model_name)
        for req in requests:
            profile.records.append(self.executor.isolated_run(req))
        return profile
