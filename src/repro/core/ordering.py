"""Incremental ordering structures for the scheduling hot path (DESIGN.md).

The seed engine re-sorted the entire waiting set with freshly recomputed
exp/log ranks every iteration and re-ranked the whole running pool for
every preemption probe — O(N log N)-per-iteration host overhead that
dominates at production queue depths. This module makes the per-iteration
bookkeeping near-constant:

``WaitingIndex``
    An incremental view of the waiting set, consumed lazily in policy rank
    order (only as many candidates as the token budget admits are ever
    drawn). Two modes:

    * static — one tombstoned heap keyed on the policy's push-time rank.
      fcfs / edf / static-priority ranks never change while a request sits
      in the queue, so the cross-request order is frozen at enqueue.
    * merge — per-class tombstoned heaps whose *within-class* key is
      time-invariant (the paper's §3.5–3.6 insight: TCM scores are monotone
      in waiting time, so FCFS-within-class order never changes); only the
      *cross-class* order ages, and that needs just a 3-way compare of the
      class heads at the current clock (tcm / naive-aging).

    One float subtlety makes "monotone" non-strict: the TCM aging term
    saturates (``1 - exp(-k·w^p)`` rounds to exactly 1.0 once the wait is
    large — ~6.6 s for motorcycles), after which every saturated request of
    a class shares one score and the seed's sort falls back to *arrival*
    order, which can differ from enqueue order after preemption requeues.
    Merge mode therefore keeps a per-class ``sat`` heap keyed by arrival
    for entries whose score has reached the class floor (it can never
    change again), and resolves transient equal-score plateaus by scanning
    the (contiguous, short) run — bit-exact against the brute-force sort.

``VictimView``
    A rank-sorted snapshot of the running+prefilling pool at one clock
    reading, so repeated ``pick_victim`` probes within an iteration cost an
    amortized scan instead of a full re-rank per probe.

Both reproduce the seed's brute-force ordering bit-for-bit, including
stable-sort tie behaviour (vehicle-class enum order, FIFO within class,
prefilling-before-waiting, first-maximal-element victim ties);
tests/test_scheduler_incremental.py enforces this against the
``SchedulerPolicy.order`` / ``pick_victim`` oracles.
"""
from __future__ import annotations

import heapq
from bisect import insort

from repro.serving.request import Request, VehicleClass

# Enum order (motorcycle, car, truck) — identical to the QueueManager's
# class-queue iteration order, which is what the seed's stable sort used to
# break rank ties.
_CLS_INDEX = {v: i for i, v in enumerate(VehicleClass)}
_NUM_CLS = len(_CLS_INDEX)


class _Entry:
    """One queued request inside a WaitingIndex heap."""
    __slots__ = ("req", "key", "cls", "seq", "alive", "deferred",
                 "saturated", "hkey", "hkey_now")

    def __init__(self, req: Request, cls: int, seq: int):
        self.req = req
        self.cls = cls
        self.seq = seq          # per-class push counter: FIFO tiebreak
        self.key = None
        self.alive = True       # tombstone flag (False once dequeued)
        self.deferred = False   # pushed during the current plan: the seed's
        self.saturated = False  # candidate snapshot excluded such requests
        self.hkey = None        # head-key memo (merge mode), keyed by clock
        self.hkey_now = None


class WaitingIndex:
    """Incremental rank-ordered view of the waiting set.

    Attach as ``QueueManager.listener``; consume between ``begin_plan`` and
    ``end_plan`` via ``next_candidate``. Drawing a candidate does not
    dequeue it — drawn entries are buffered and restored by ``end_plan``,
    so candidates that fail admission stay queued.

    Clock contract: ``begin_plan``/``next_candidate`` times must be
    non-decreasing across calls (the engine clock is monotone) — once an
    entry's ``ready_at`` has passed, or its score has saturated, it stays
    that way.
    """

    def __init__(self, static_key=None, within_key=None, head_key=None,
                 score_floor=None):
        if (static_key is None) == (within_key is None):
            raise ValueError("exactly one of static_key/within_key required")
        self._static_key = static_key     # req -> rank frozen at push
        self._within_key = within_key     # (req, seq) -> within-class key
        self._head_key = head_key         # (req, now) -> policy.rank(req, now)
        self._merge = static_key is None
        if self._merge:
            self._heaps: list[list] = [[] for _ in range(_NUM_CLS)]
            self._staged: list = [None] * _NUM_CLS
            if score_floor is not None:
                # terminal (saturated) score per class index; head_key[0]
                # equal to it can never change again
                self._floors = [score_floor[v] for v in VehicleClass]
                self._sats: list[list] | None = [[] for _ in range(_NUM_CLS)]
            else:
                self._floors = None
                self._sats = None
        else:
            self._heap: list = []
        self._pending: list = []          # (ready_at, cls, seq, entry)
        self._entries: dict[str, _Entry] = {}
        self._seq = [0] * _NUM_CLS
        self._in_plan = False
        self._deferred: list[_Entry] = []
        self._popped: list[_Entry] = []

    def __len__(self) -> int:
        return len(self._entries)

    # -- queue events ------------------------------------------------------
    def on_push(self, req: Request, now: float) -> None:
        cls = _CLS_INDEX[req.vclass]
        self._seq[cls] += 1
        e = _Entry(req, cls, self._seq[cls])
        assert req.rid not in self._entries, f"{req.rid} double-queued"
        self._entries[req.rid] = e
        if self._in_plan:
            e.deferred = True
            self._deferred.append(e)
        if req.ready_at > now:
            heapq.heappush(self._pending, (req.ready_at, cls, e.seq, e))
        else:
            self._insert(e)

    def on_remove(self, req: Request) -> None:
        e = self._entries.pop(req.rid, None)
        if e is not None:
            e.alive = False

    # -- internals ---------------------------------------------------------
    def _insert(self, e: _Entry) -> None:
        if not self._merge:
            e.key = (self._static_key(e.req), e.cls, e.seq)
            heapq.heappush(self._heap, (e.key, e))
        elif e.saturated:
            heapq.heappush(self._sats[e.cls], ((e.req.arrival, e.seq), e))
        else:
            e.key = self._within_key(e.req, e.seq)
            heapq.heappush(self._heaps[e.cls], (e.key, e))

    def _mature(self, now: float) -> None:
        pend = self._pending
        while pend and pend[0][0] <= now:
            e = heapq.heappop(pend)[3]
            if e.alive:
                self._insert(e)

    def _live_head(self, h: list) -> _Entry | None:
        """Live, non-deferred head of one heap."""
        while h:
            e = h[0][1]
            if not e.alive:
                heapq.heappop(h)
            elif e.deferred:
                self._popped.append(heapq.heappop(h)[1])
            else:
                return e
        return None

    def _hkey(self, e: _Entry, now: float):
        if e.hkey_now != now:
            e.hkey = self._head_key(e.req, now)
            e.hkey_now = now
        return e.hkey

    def _stage_class(self, cls: int, now: float) -> _Entry | None:
        """Extract (and cache) this class's oracle-best entry."""
        e = self._staged[cls]
        if e is not None:
            if e.alive:
                return e
            self._staged[cls] = None
        uns = self._heaps[cls]
        if self._sats is not None:
            sat = self._sats[cls]
            floor = self._floors[cls]
            # migrate the permanently-saturated prefix (score monotonically
            # non-decreasing along within-key order, so it is a prefix)
            while True:
                e = self._live_head(uns)
                if e is None or self._hkey(e, now)[0] != floor:
                    break
                heapq.heappop(uns)
                e.saturated = True
                heapq.heappush(sat, ((e.req.arrival, e.seq), e))
            e = self._live_head(sat)
            if e is not None:
                # floor score <= any unsaturated score: class-best for sure
                heapq.heappop(sat)
                self._staged[cls] = e
                return e
        e0 = self._live_head(uns)
        if e0 is None:
            return None
        heapq.heappop(uns)
        if self._sats is not None:
            # transient equal-score plateau (float-quantized aging near
            # saturation): the seed's sort orders such ties by arrival, not
            # enqueue — resolve over the contiguous run
            s0 = self._hkey(e0, now)[0]
            run = [e0]
            while True:
                e = self._live_head(uns)
                if e is None or self._hkey(e, now)[0] != s0:
                    break
                run.append(heapq.heappop(uns)[1])
            e0 = min(run, key=lambda x: self._hkey(x, now))
            for e in run:
                if e is not e0:
                    heapq.heappush(uns, (e.key, e))
        self._staged[cls] = e0
        return e0

    # -- plan-scoped ordered consumption -----------------------------------
    def begin_plan(self, now: float) -> None:
        self._in_plan = True
        self._mature(now)

    def next_candidate(self, now: float):
        """(rank, request) for the next ready waiting request in policy
        rank order, or None when exhausted. ``rank`` compares like
        ``policy.rank(request, now)``."""
        if not self._merge:
            h = self._heap
            while h:
                key, e = heapq.heappop(h)
                if not e.alive:
                    continue
                self._popped.append(e)
                if not e.deferred:
                    return key[0], e.req
            return None
        best_e, best_key, best_cls = None, None, -1
        for cls in range(_NUM_CLS):
            e = self._stage_class(cls, now)
            if e is None:
                continue
            k = (self._hkey(e, now), cls)
            if best_e is None or k < best_key:
                best_e, best_key, best_cls = e, k, cls
        if best_e is None:
            return None
        self._staged[best_cls] = None
        self._popped.append(best_e)
        return best_e.hkey, best_e.req

    def end_plan(self) -> None:
        if self._merge:
            for cls in range(_NUM_CLS):
                e = self._staged[cls]
                if e is not None:
                    if e.alive:
                        self._insert(e)
                    self._staged[cls] = None
        for e in self._popped:
            if e.alive:
                self._insert(e)
        self._popped = []
        for e in self._deferred:
            e.deferred = False
        self._deferred = []
        self._in_plan = False


class VictimView:
    """Rank-sorted view of the running+prefilling pool at one clock.

    Reproduces ``max(pool, key=rank)`` over the eligible pool exactly:
    among rank ties the entry earliest in pool order wins (``max`` returns
    the first maximal element), and additions always rank after existing
    equal-rank entries (new admissions append to the pool).
    """
    __slots__ = ("_key", "_eligible", "_dead", "_seq", "_seq_of", "_entries")

    def __init__(self, pool: list[Request], key, eligible=None):
        self._key = key
        self._eligible = eligible
        # staleness is per entry (seq), not per request: a request can be
        # preempted and re-admitted at the same clock, and only its old
        # tuple (stale rank) must stay dead
        self._dead: set[int] = set()
        self._seq = len(pool)
        self._seq_of = {r.rid: i for i, r in enumerate(pool)}
        self._entries = sorted((key(r), i, r) for i, r in enumerate(pool))

    def add(self, req: Request) -> None:
        insort(self._entries, (self._key(req), self._seq, req))
        self._seq_of[req.rid] = self._seq
        self._seq += 1

    def discard(self, req: Request) -> None:
        seq = self._seq_of.pop(req.rid, None)
        if seq is not None:
            self._dead.add(seq)

    def pick(self, bar=None, exclude: Request | None = None):
        """Highest-ranked eligible victim, or None. With ``bar`` set, the
        victim's rank must be strictly greater (strictly lower priority —
        prevents preemption cycles)."""
        entries = self._entries
        best = None
        for i in range(len(entries) - 1, -1, -1):
            key, seq, req = entries[i]
            if best is not None and key < best[0]:
                break  # keys only decrease leftwards; best is settled
            if (seq not in self._dead and req is not exclude
                    and (self._eligible is None or self._eligible(req))):
                # equal keys scan right-to-left with decreasing seq, so any
                # later hit is earlier in pool order — take it
                best = (key, seq, req)
        if best is None or (bar is not None and not best[0] > bar):
            return None
        return best[2]
