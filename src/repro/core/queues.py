"""Queue Manager (paper §3.5).

Three independent FIFO queues (trucks, cars, motorcycles) with queue-level
metrics (length, waiting time, aggregate estimated prefill). FCFS is
preserved *within* each queue; cross-queue ordering is delegated to the
Priority Regulator via the scheduler.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

from repro.serving.request import Request, VehicleClass


@dataclass
class QueueManager:
    queues: dict = field(default_factory=lambda: {
        v: deque() for v in VehicleClass})

    def push(self, req: Request, now: float) -> None:
        assert req.vclass is not None, "classify before enqueue"
        req.enqueue_time = now
        self.queues[req.vclass].append(req)

    def remove(self, req: Request) -> None:
        self.queues[req.vclass].remove(req)

    def __len__(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def peek_all(self) -> list[Request]:
        return [r for q in self.queues.values() for r in q]

    def heads(self) -> list[Request]:
        """FCFS head of each class queue (candidates for cross-queue pick)."""
        return [q[0] for q in self.queues.values() if q]

    def metrics(self, now: float) -> dict:
        out = {}
        for v, q in self.queues.items():
            waits = [r.waiting_time(now) for r in q]
            out[v.value] = {
                "len": len(q),
                "avg_wait": sum(waits) / len(waits) if waits else 0.0,
                "est_prefill_sum": sum(r.est_prefill for r in q),
            }
        return out
