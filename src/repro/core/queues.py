"""Queue Manager (paper §3.5) with O(1) hot-path bookkeeping.

Three independent FIFO queues (trucks, cars, motorcycles) with queue-level
metrics (length, waiting time, aggregate estimated prefill). FCFS is
preserved *within* each queue; cross-queue ordering is delegated to the
Priority Regulator via the scheduler.

The seed backed each queue with a ``deque`` whose ``remove`` was O(N) per
admission. Queues are now insertion-ordered dicts (O(1) push / remove /
membership) with incrementally-maintained aggregates, and they notify an
attached ``listener`` (the engine's ``WaitingIndex``, see core/ordering.py)
so the scheduler's rank order stays incremental too.
"""
from __future__ import annotations

from repro.serving.request import Request, VehicleClass


class ClassQueue:
    """One class's FIFO: insertion-ordered dict keyed by rid, plus cached
    aggregate sums (est_prefill, enqueue_time) for O(1) queue metrics."""
    __slots__ = ("_reqs", "est_prefill_sum", "enqueue_sum")

    def __init__(self):
        self._reqs: dict[str, Request] = {}
        self.est_prefill_sum = 0.0
        self.enqueue_sum = 0.0

    def push(self, req: Request) -> None:
        self._reqs[req.rid] = req
        self.est_prefill_sum += req.est_prefill
        self.enqueue_sum += req.enqueue_time

    def remove(self, req: Request) -> None:
        del self._reqs[req.rid]
        self.est_prefill_sum -= req.est_prefill
        self.enqueue_sum -= req.enqueue_time
        if not self._reqs:  # pin cached float sums on empty
            self.est_prefill_sum = 0.0
            self.enqueue_sum = 0.0

    def head(self) -> Request | None:
        return next(iter(self._reqs.values())) if self._reqs else None

    def __contains__(self, req: Request) -> bool:
        return req.rid in self._reqs

    def __len__(self) -> int:
        return len(self._reqs)

    def __iter__(self):
        return iter(self._reqs.values())

    def __getitem__(self, i: int) -> Request:
        if i == 0:
            head = self.head()
            if head is not None:
                return head
            raise IndexError(i)
        return list(self._reqs.values())[i]


class QueueManager:
    def __init__(self):
        self.queues: dict[VehicleClass, ClassQueue] = {
            v: ClassQueue() for v in VehicleClass}
        self.listener = None  # WaitingIndex attached by the engine
        self._len = 0

    def push(self, req: Request, now: float) -> None:
        assert req.vclass is not None, "classify before enqueue"
        req.enqueue_time = now
        self.queues[req.vclass].push(req)
        self._len += 1
        if self.listener is not None:
            self.listener.on_push(req, now)

    def remove(self, req: Request) -> None:
        self.queues[req.vclass].remove(req)
        self._len -= 1
        if self.listener is not None:
            self.listener.on_remove(req)

    def __len__(self) -> int:
        return self._len

    def peek_all(self) -> list[Request]:
        return [r for q in self.queues.values() for r in q]

    def heads(self) -> list[Request]:
        """FCFS head of each class queue (candidates for cross-queue pick)."""
        return [q.head() for q in self.queues.values() if q]

    def metrics(self, now: float) -> dict:
        """Queue-level aggregates from the cached sums — O(classes), not
        O(requests) (float drift vs. a fresh sum is ~ulp-scale)."""
        out = {}
        for v, q in self.queues.items():
            n = len(q)
            avg_wait = max(0.0, now - q.enqueue_sum / n) if n else 0.0
            out[v.value] = {
                "len": n,
                "avg_wait": avg_wait,
                "est_prefill_sum": q.est_prefill_sum,
            }
        return out
