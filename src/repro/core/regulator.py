"""Priority Regulator (paper §3.6).

    Priority_c = StaticPriority_c + (1 - exp(-k_c * waiting_time^{p_c}))
    Score_c    = -log(Priority_c)           (lower score -> scheduled earlier)

Paper constants (§4.1 Configuration):
    StaticPriority: M=0.1,  C=0.05,  T=0.0
    p:              M=3.5,  C=2.5,   T=1.1
    k:              M=0.05, C=0.003, T=0.00075
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.serving.request import Request, VehicleClass

PAPER_PARAMS = {
    VehicleClass.MOTORCYCLE: dict(static=0.10, k=0.05, p=3.5),
    VehicleClass.CAR: dict(static=0.05, k=0.003, p=2.5),
    VehicleClass.TRUCK: dict(static=0.00, k=0.00075, p=1.1),
}

EPS = 1e-12


@dataclass
class PriorityRegulator:
    params: dict = field(default_factory=lambda: dict(PAPER_PARAMS))

    def priority(self, vclass: VehicleClass, waiting_time: float) -> float:
        c = self.params[vclass]
        wait = max(0.0, waiting_time)
        age = 1.0 - math.exp(-c["k"] * (wait ** c["p"]))
        return c["static"] + age

    def score(self, vclass: VehicleClass, waiting_time: float) -> float:
        """-log(priority): lower = earlier (vLLM-style score ordering)."""
        return -math.log(max(self.priority(vclass, waiting_time), EPS))

    def request_score(self, req: Request, now: float) -> float:
        """Inlined ``score(vclass, waiting_time)`` — the scheduler hot path
        calls this per queue-head comparison, so skip the method hops while
        keeping the exact expression order (bit-identical results)."""
        c = self.params[req.vclass]
        wait = max(0.0, now - req.enqueue_time)
        age = 1.0 - math.exp(-c["k"] * (wait ** c["p"]))
        return -math.log(max(c["static"] + age, EPS))
