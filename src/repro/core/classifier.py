"""Request Classifier (paper §3.4).

Smart classifier: k-means (k=3, Lloyd iterations in JAX) over resource-aware
features — (log prefill-latency estimate, log KV-token estimate) — trained
on profiling data. Clusters are ranked by centroid magnitude: smallest =
motorcycles, middle = cars, largest = trucks.

Naive classifier (the paper's ablation): modality -> class
(text->motorcycle, image->car, video->truck).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.request import Modality, VehicleClass

from .estimator import ImpactEstimator
from .profiler import Profile

CLASS_ORDER = [VehicleClass.MOTORCYCLE, VehicleClass.CAR, VehicleClass.TRUCK]


def _features(prefill: np.ndarray, kv: np.ndarray) -> np.ndarray:
    return np.stack([np.log10(np.maximum(prefill, 1e-5)),
                     np.log10(np.maximum(kv, 1.0))], axis=1)


def kmeans(x: jnp.ndarray, k: int = 3, iters: int = 50,
           seed: int = 0) -> jnp.ndarray:
    """Lloyd's algorithm under lax.scan; k-means++-ish spread init."""
    # init: spread over the feature range by quantile (deterministic)
    qs = jnp.linspace(0.05, 0.95, k)
    init = jnp.quantile(x, qs, axis=0)

    def step(cent, _):
        d = jnp.linalg.norm(x[:, None] - cent[None], axis=-1)  # (n,k)
        assign = jnp.argmin(d, axis=1)
        oh = jax.nn.one_hot(assign, k)                          # (n,k)
        counts = oh.sum(0)[:, None]
        new = (oh.T @ x) / jnp.maximum(counts, 1.0)
        cent = jnp.where(counts > 0, new, cent)
        return cent, None

    cent, _ = jax.lax.scan(step, init, None, length=iters)
    return cent


class SmartClassifier:
    """Resource-aware clustering classifier."""

    def __init__(self, estimator: ImpactEstimator, centroids: np.ndarray):
        self.estimator = estimator
        # rank clusters: ascending by centroid L2 (log-space) => M, C, T
        order = np.argsort(np.linalg.norm(centroids, axis=1))
        self.centroids = centroids[order]

    @classmethod
    def train(cls, estimator: ImpactEstimator,
              profile: Profile) -> "SmartClassifier":
        preds = np.array([
            estimator.predict(r.modality, r.text_tokens, r.mm_units)
            for r in profile.records])
        feats = _features(preds[:, 0], preds[:, 1])
        cent = np.asarray(kmeans(jnp.asarray(feats)))
        return cls(estimator, cent)

    def classify(self, modality: str, text_tokens: int,
                 mm_units: int = 0) -> tuple[VehicleClass, float, float]:
        """Returns (class, est_prefill_s, est_kv_tokens)."""
        prefill, kv = self.estimator.predict(modality, text_tokens, mm_units)
        f = _features(np.array([prefill]), np.array([kv]))[0]
        d = np.linalg.norm(self.centroids - f[None], axis=1)
        return CLASS_ORDER[int(np.argmin(d))], prefill, kv


class NaiveClassifier:
    """Pure modality mapping (ablation baseline)."""

    def __init__(self, estimator: ImpactEstimator | None = None):
        self.estimator = estimator

    def classify(self, modality: str, text_tokens: int,
                 mm_units: int = 0) -> tuple[VehicleClass, float, float]:
        mapping = {
            Modality.TEXT.value: VehicleClass.MOTORCYCLE,
            Modality.IMAGE.value: VehicleClass.CAR,
            Modality.VIDEO.value: VehicleClass.TRUCK,
            Modality.AUDIO.value: VehicleClass.CAR,
        }
        prefill, kv = (0.0, float(text_tokens + mm_units))
        if self.estimator is not None:
            prefill, kv = self.estimator.predict(modality, text_tokens, mm_units)
        return mapping[modality], prefill, kv
