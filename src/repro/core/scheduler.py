"""Scheduling policies: TCM-Serve and the paper's baselines.

Each policy defines a total order over requests via ``rank`` (lower = run
earlier). The engine uses the policy's *incremental* structures on the hot
path — ``make_waiting_index`` for admission order and ``make_victim_view``
for preemption under memory pressure (core/ordering.py) — while ``order``
and ``pick_victim`` remain the brute-force reference implementations: they
are the oracle the property tests compare against and the code path behind
``EngineConfig.legacy_scheduling``. Victim selection for *admission*
requires the victim to rank strictly LOWER than the candidate (prevents
preemption cycles; matches vLLM's priority preemption).

Incremental orderings per policy (bit-identical to the brute-force sort):
  * fcfs / edf / static — rank is frozen at enqueue, so one heap keyed on
    the static rank suffices.
  * tcm / naive-aging  — rank ages with waiting time, but FCFS *within* a
    class never changes (paper §3.5–3.6: scores are monotone in waiting
    time within a class), so cross-queue order needs only a lazy 3-way
    merge of the per-class FIFO heads — never a global sort.

Policies:
  * fcfs            — vLLM default (arrival order).
  * edf             — Earliest Deadline First (deadline = arrival + SLO).
  * static          — static M->C->T priority, FCFS within class.
  * naive-aging     — priority purely by age (ablation).
  * tcm             — full TCM-Serve: smart classifier + Priority Regulator
                      (aging); motorcycles are never preempted.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.serving.request import Request, VehicleClass

from .ordering import VictimView, WaitingIndex
from .regulator import EPS, PriorityRegulator

CLASS_RANK = {VehicleClass.MOTORCYCLE: 0, VehicleClass.CAR: 1,
              VehicleClass.TRUCK: 2}


class SchedulerPolicy:
    name = "base"

    def rank(self, req: Request, now: float):
        """Sortable key; lower = scheduled earlier."""
        raise NotImplementedError

    def victim_eligible(self, req: Request) -> bool:
        """May this request ever be preempted? (tcm shields motorcycles)"""
        return True

    # -- brute-force reference (oracle + legacy_scheduling path) ----------
    def order(self, waiting: list[Request], now: float) -> list[Request]:
        return sorted(waiting, key=lambda r: self.rank(r, now))

    def _victim_pool(self, running: list[Request], now: float,
                     for_req: Request | None):
        pool = running
        if for_req is not None:
            bar = self.rank(for_req, now)
            pool = [r for r in pool if self.rank(r, now) > bar]
        return pool

    def pick_victim(self, running: list[Request], now: float,
                    for_req: Request | None = None) -> Request | None:
        """Request to preempt (None = don't preempt). If ``for_req`` is
        given, only strictly lower-priority requests are eligible."""
        pool = [r for r in self._victim_pool(running, now, for_req)
                if self.victim_eligible(r)]
        if not pool:
            return None
        return max(pool, key=lambda r: self.rank(r, now))

    # -- incremental structures (engine hot path) -------------------------
    def make_waiting_index(self) -> WaitingIndex:
        """Default: rank is time-invariant while queued — freeze it at
        push (``rank(req, now)`` must not depend on ``now``)."""
        return WaitingIndex(static_key=lambda r: self.rank(r, 0.0))

    def make_victim_view(self, pool: list[Request],
                         now: float) -> VictimView:
        return VictimView(pool, key=lambda r: self.rank(r, now),
                          eligible=self.victim_eligible)


class FCFSPolicy(SchedulerPolicy):
    """vLLM default: first-come-first-served (+ chunked prefill in engine)."""
    name = "fcfs"

    def rank(self, req, now):
        return req.arrival


class EDFPolicy(SchedulerPolicy):
    """Earliest-deadline-first; aggressive deadline-driven preemption."""
    name = "edf"

    def rank(self, req, now):
        return req.arrival + req.slo


class StaticPriorityPolicy(SchedulerPolicy):
    """Motorcycles -> cars -> trucks, FCFS within class (paper §3.4 study)."""
    name = "static"

    def rank(self, req, now):
        return (CLASS_RANK[req.vclass], req.arrival)


class NaiveAgingPolicy(SchedulerPolicy):
    """Priority purely by age, ignoring the class hierarchy (ablation)."""
    name = "naive-aging"

    def rank(self, req, now):
        return req.enqueue_time

    def make_waiting_index(self):
        # Within a class, enqueue order IS rank order; across classes only
        # the heads need comparing. (Tie order matches the seed's stable
        # sort: class enum order, then FIFO position.)
        return WaitingIndex(
            within_key=lambda r, seq: (r.enqueue_time, seq),
            head_key=lambda r, now: r.enqueue_time)


@dataclass
class TCMPolicy(SchedulerPolicy):
    """Full TCM-Serve: dynamic priority = static class priority + aging.

    Scores are recomputed every scheduling iteration (the Priority
    Regulator 'continuously revisits priorities') — but only for the three
    class-queue heads: within a class the score is monotone in waiting
    time, so (enqueue_time, arrival) order is score order and never needs
    re-sorting. Motorcycles are never preempted (paper Fig. 11 shows zero
    motorcycle preemptions).
    """
    regulator: PriorityRegulator = field(default_factory=PriorityRegulator)
    name = "tcm"

    def rank(self, req, now):
        return (self.regulator.request_score(req, now), req.arrival)

    def victim_eligible(self, req):
        return req.vclass is not VehicleClass.MOTORCYCLE

    def make_waiting_index(self):
        reg = self.regulator
        # terminal score per class: the aging term rounds to exactly 1.0 at
        # large waits, so the score bottoms out at -log(static + 1) and the
        # seed's sort starts breaking those ties by arrival (see
        # ordering.py on saturation); computed with the same float ops as
        # request_score for bit equality
        floors = {v: -math.log(max(reg.params[v]["static"] + 1.0, EPS))
                  for v in VehicleClass}
        return WaitingIndex(
            within_key=lambda r, seq: (r.enqueue_time, r.arrival, seq),
            head_key=lambda r, now: (reg.request_score(r, now), r.arrival),
            score_floor=floors)


def make_policy(name: str) -> SchedulerPolicy:
    return {
        "fcfs": FCFSPolicy,
        "edf": EDFPolicy,
        "static": StaticPriorityPolicy,
        "naive-aging": NaiveAgingPolicy,
        "tcm": TCMPolicy,
    }[name]()
