"""Scheduling policies: TCM-Serve and the paper's baselines.

Each policy defines a total order over requests via ``rank`` (lower = run
earlier). The engine uses ``order`` for admission each iteration and
``pick_victim`` for preemption under memory pressure. Victim selection for
*admission* requires the victim to rank strictly LOWER than the candidate
(prevents preemption cycles; matches vLLM's priority preemption).

Policies:
  * fcfs            — vLLM default (arrival order).
  * edf             — Earliest Deadline First (deadline = arrival + SLO).
  * static          — static M->C->T priority, FCFS within class.
  * naive-aging     — priority purely by age (ablation).
  * tcm             — full TCM-Serve: smart classifier + Priority Regulator
                      (aging); motorcycles are never preempted.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.serving.request import Request, VehicleClass

from .regulator import PriorityRegulator

CLASS_RANK = {VehicleClass.MOTORCYCLE: 0, VehicleClass.CAR: 1,
              VehicleClass.TRUCK: 2}


class SchedulerPolicy:
    name = "base"

    def rank(self, req: Request, now: float):
        """Sortable key; lower = scheduled earlier."""
        raise NotImplementedError

    def order(self, waiting: list[Request], now: float) -> list[Request]:
        return sorted(waiting, key=lambda r: self.rank(r, now))

    def _victim_pool(self, running: list[Request], now: float,
                     for_req: Request | None):
        pool = running
        if for_req is not None:
            bar = self.rank(for_req, now)
            pool = [r for r in pool if self.rank(r, now) > bar]
        return pool

    def pick_victim(self, running: list[Request], now: float,
                    for_req: Request | None = None) -> Request | None:
        """Request to preempt (None = don't preempt). If ``for_req`` is
        given, only strictly lower-priority requests are eligible."""
        pool = self._victim_pool(running, now, for_req)
        if not pool:
            return None
        return max(pool, key=lambda r: self.rank(r, now))


class FCFSPolicy(SchedulerPolicy):
    """vLLM default: first-come-first-served (+ chunked prefill in engine)."""
    name = "fcfs"

    def rank(self, req, now):
        return req.arrival


class EDFPolicy(SchedulerPolicy):
    """Earliest-deadline-first; aggressive deadline-driven preemption."""
    name = "edf"

    def rank(self, req, now):
        return req.arrival + req.slo


class StaticPriorityPolicy(SchedulerPolicy):
    """Motorcycles -> cars -> trucks, FCFS within class (paper §3.4 study)."""
    name = "static"

    def rank(self, req, now):
        return (CLASS_RANK[req.vclass], req.arrival)


class NaiveAgingPolicy(SchedulerPolicy):
    """Priority purely by age, ignoring the class hierarchy (ablation)."""
    name = "naive-aging"

    def rank(self, req, now):
        return req.enqueue_time


@dataclass
class TCMPolicy(SchedulerPolicy):
    """Full TCM-Serve: dynamic priority = static class priority + aging.

    Scores are recomputed every scheduling iteration (the Priority
    Regulator 'continuously revisits priorities'). Motorcycles are never
    preempted (paper Fig. 11 shows zero motorcycle preemptions).
    """
    regulator: PriorityRegulator = field(default_factory=PriorityRegulator)
    name = "tcm"

    def rank(self, req, now):
        return (self.regulator.request_score(req, now), req.arrival)

    def pick_victim(self, running, now, for_req=None):
        pool = [r for r in self._victim_pool(running, now, for_req)
                if r.vclass is not VehicleClass.MOTORCYCLE]
        if not pool:
            return None
        return max(pool, key=lambda r: self.rank(r, now))


def make_policy(name: str) -> SchedulerPolicy:
    return {
        "fcfs": FCFSPolicy,
        "edf": EDFPolicy,
        "static": StaticPriorityPolicy,
        "naive-aging": NaiveAgingPolicy,
        "tcm": TCMPolicy,
    }[name]()
