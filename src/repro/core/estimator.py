"""Impact Estimator (paper §3.3).

Predicts per-request *prefill latency* and *KV-cache footprint* from request
metadata, using profiling data:

  * text     — ordinary linear regression on prompt length (closed form),
    "consistent with prior works" [paper].
  * image / video — quantile regression at q=0.90 (pinball loss, fitted with
    JAX gradient descent) "to avoid underestimation and protect SLO
    compliance" [paper].

KV footprint is fitted with per-modality linear regression on
(text_tokens, mm_units) — vision tokenizers are near-deterministic in the
input size, so this is essentially exact.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .profiler import Profile


def _design(X: np.ndarray) -> np.ndarray:
    return np.concatenate([np.ones((len(X), 1)), X], axis=1)


def fit_linreg(X: np.ndarray, y: np.ndarray) -> np.ndarray:
    A = _design(X)
    w, *_ = np.linalg.lstsq(A, y, rcond=None)
    return w


def fit_quantile(X: np.ndarray, y: np.ndarray, q: float = 0.9,
                 steps: int = 2000, lr: float = 0.05) -> np.ndarray:
    """Pinball-loss quantile regression via Adam in JAX."""
    A = jnp.asarray(_design(X))
    yj = jnp.asarray(y)
    scale = jnp.maximum(jnp.abs(A).max(axis=0), 1e-9)
    An = A / scale

    def loss(w):
        resid = yj - An @ w
        return jnp.mean(jnp.maximum(q * resid, (q - 1) * resid))

    w = jnp.zeros(A.shape[1])
    m = jnp.zeros_like(w)
    v = jnp.zeros_like(w)
    g_fn = jax.jit(jax.grad(loss))

    def step(carry, i):
        w, m, v = carry
        g = g_fn(w)
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1 - 0.9 ** (i + 1.0))
        vh = v / (1 - 0.999 ** (i + 1.0))
        w = w - lr * mh / (jnp.sqrt(vh) + 1e-8)
        return (w, m, v), None

    (w, _, _), _ = jax.lax.scan(step, (w, m, v), jnp.arange(steps))
    return np.asarray(w / scale)


@dataclass
class ModalityModel:
    w_time: np.ndarray   # prefill-time weights (1, text_tokens, mm_units)
    w_kv: np.ndarray     # kv-token weights
    kind: str            # "linreg" | "quantile"


class ImpactEstimator:
    """Trained once per (model, modality) from the Workload Profiler's data;
    at runtime predicts (prefill_latency_s, kv_tokens) per request."""

    QUANTILE_MODALITIES = ("image", "video", "audio")

    def __init__(self):
        self.models: dict[str, ModalityModel] = {}

    @classmethod
    def train(cls, profile: Profile) -> "ImpactEstimator":
        est = cls()
        for modality in sorted({r.modality for r in profile.records}):
            X, t, kv = profile.features(modality)
            if modality in cls.QUANTILE_MODALITIES:
                w_time = fit_quantile(X, t, q=0.9)
                kind = "quantile"
            else:
                w_time = fit_linreg(X, t)
                kind = "linreg"
            w_kv = fit_linreg(X, kv)
            est.models[modality] = ModalityModel(w_time, w_kv, kind)
        return est

    def predict(self, modality: str, text_tokens: int,
                mm_units: int = 0) -> tuple[float, float]:
        m = self.models[modality]
        x = np.array([1.0, text_tokens, mm_units])
        prefill = float(max(x @ m.w_time, 1e-4))
        kv = float(max(x @ m.w_kv, 1.0))
        return prefill, kv

    def errors(self, profile: Profile) -> dict[str, np.ndarray]:
        """Absolute prediction errors per modality (paper Fig. 7)."""
        out = {}
        for modality, m in self.models.items():
            X, t, _ = profile.features(modality)
            pred = _design(X) @ m.w_time
            out[modality] = np.abs(pred - t)
        return out
