"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the kernel tests assert against
(``jnp.allclose`` sweeps over shapes/dtypes, interpret mode).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ref_prefill_attention(q, k, v, *, q_start: int = 0, window: int = 0,
                          softcap: float = 0.0):
    """Chunked-prefill causal attention oracle.

    q: (B, Sq, H, hd) — queries at global positions [q_start, q_start+Sq)
    k, v: (B, Skv, KV, hd) — the full context so far (Skv >= q_start+Sq)
    window: sliding window size (0 = full causal)
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qpos = q_start + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = kpos <= qpos
    if window > 0:
        mask = mask & (kpos > qpos - window)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ref_paged_attention(q, k_pages, v_pages, block_table, lengths, *,
                        softcap: float = 0.0):
    """Decode-phase paged attention oracle.

    q: (B, H, hd) — one query token per sequence
    k_pages/v_pages: (num_pages, page_size, KV, hd)
    block_table: (B, max_pages) int32 — page ids per sequence
    lengths: (B,) int32 — context length (tokens) per sequence
    """
    B, H, hd = q.shape
    P, page_size, KV, _ = k_pages.shape
    max_pages = block_table.shape[1]
    # gather pages into contiguous (B, max_pages*page_size, KV, hd)
    k = k_pages[block_table].reshape(B, max_pages * page_size, KV, hd)
    v = v_pages[block_table].reshape(B, max_pages * page_size, KV, hd)
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    kpos = jnp.arange(max_pages * page_size)[None, :]
    mask = kpos < lengths[:, None]
    logits = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = jnp.where(mask[:, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", w, v.astype(jnp.float32))
    # length-0 guard: a fully-masked row would softmax to uniform weights
    # over garbage; zero it to match the kernel's empty-accumulator output
    # (padding rows in bucketed batches hit this).
    out = jnp.where(lengths[:, None, None] > 0, out, 0.0)
    return out.astype(q.dtype)


def ref_paged_prefill_attention(q, k_pages, v_pages, block_table, q_start,
                                new_lens, *, softcap: float = 0.0):
    """Packed chunked-prefill attention over the paged KV cache.

    The batched executor's prefill path: each row's chunk has already been
    scattered into its pages; queries attend causally over the gathered
    context.  Ragged per-sequence geometry rides in vectors:

    q: (B, S, H, hd) — right-padded chunks at per-row global positions
       [q_start[b], q_start[b] + new_lens[b]);
    k_pages/v_pages: (num_pages, page_size, KV, hd);
    block_table: (B, max_pages) int32;
    q_start: (B,) int32 context tokens before this chunk;
    new_lens: (B,) int32 valid chunk tokens (<= S).  Outputs at padding
    positions (i >= new_lens[b]) are zeroed.
    """
    B, S, H, hd = q.shape
    P, page_size, KV, _ = k_pages.shape
    max_pages = block_table.shape[1]
    k = k_pages[block_table].reshape(B, max_pages * page_size, KV, hd)
    v = v_pages[block_table].reshape(B, max_pages * page_size, KV, hd)
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qpos = q_start[:, None] + jnp.arange(S)[None, :]          # (B, S)
    kpos = jnp.arange(max_pages * page_size)[None, None, :]
    mask = kpos <= qpos[:, :, None]                           # (B, S, Tk)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = jnp.where(mask[:, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    pad = jnp.arange(S)[None, :] < new_lens[:, None]          # (B, S)
    out = jnp.where(pad[:, :, None, None], out, 0.0)
    return out.astype(q.dtype)
