"""Chunked-prefill flash attention Pallas kernel (TPU target).

The serving engine's prefill hot-spot: a chunk of Sq queries (global
positions q_start..q_start+Sq) attends over the full Skv context written so
far. Online-softmax accumulation over key blocks; MXU-aligned 128 tiles.

Grid: (B, H, nq, nk) with the key-block axis innermost; running max/sum and
the output accumulator live in VMEM scratch and are re-initialized at k==0,
finalized at k==nk-1 (canonical TPU flash pattern).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            q_start: int, window: int, softcap: float, bq: int, bk: int,
            nk: int, sm_scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)          # (bk, hd)

    qpos = q_start + qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos <= qpos
    if window > 0:
        mask = mask & (kpos > qpos - window)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * sm_scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                          # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                       # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)              # (bq, 1)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("q_start", "window", "softcap",
                                             "bq", "bk", "interpret"))
def prefill_attention(q, k, v, *, q_start: int = 0, window: int = 0,
                      softcap: float = 0.0, bq: int = 128, bk: int = 128,
                      interpret: bool = True):
    """q: (B,Sq,H,hd); k/v: (B,Skv,KV,hd). Returns (B,Sq,H,hd).

    GQA handled by replicating kv heads at the wrapper level (ops.py keeps
    the HBM-resident cache deduplicated; the repeat happens on the fly).
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    pad_q = (-Sq) % bq
    pad_k = (-Skv) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sq_p, Skv_p = q.shape[1], k.shape[1]
    nq, nk = Sq_p // bq, Skv_p // bk

    # layout: (B, H, S, hd) so the head dim is a grid axis
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _kernel, q_start=q_start, window=window, softcap=softcap, bq=bq,
        bk=bk, nk=nk, sm_scale=1.0 / math.sqrt(hd))

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out.transpose(0, 2, 1, 3)
    if pad_q:
        out = out[:, :Sq]
    return out
