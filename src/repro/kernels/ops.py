"""Jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run in interpret mode (the kernel body
executes in Python); on TPU set ``interpret=False``. ``ref.py`` holds the
pure-jnp oracles used by tests and by the engine's portable fallback path.
"""
from __future__ import annotations

import jax

from .paged_attention import paged_attention as _paged
from .paged_prefill_attention import \
    paged_prefill_attention as _paged_prefill
from .prefill_attention import prefill_attention as _prefill
from .ref import (ref_paged_attention, ref_paged_prefill_attention,
                  ref_prefill_attention)

# flipped to False on real TPU deployments
INTERPRET = jax.default_backend() != "tpu"


def prefill_attention(q, k, v, *, q_start=0, window=0, softcap=0.0,
                      use_kernel=True):
    if not use_kernel:
        return ref_prefill_attention(q, k, v, q_start=q_start, window=window,
                                     softcap=softcap)
    return _prefill(q, k, v, q_start=q_start, window=window, softcap=softcap,
                    interpret=INTERPRET)


def paged_attention(q, k_pages, v_pages, block_table, lengths, *, softcap=0.0,
                    use_kernel=True):
    if not use_kernel:
        return ref_paged_attention(q, k_pages, v_pages, block_table, lengths,
                                   softcap=softcap)
    return _paged(q, k_pages, v_pages, block_table, lengths, softcap=softcap,
                  interpret=INTERPRET)


def paged_prefill_attention(q, k_pages, v_pages, block_table, q_start,
                            new_lens, *, softcap=0.0, use_kernel=True):
    if not use_kernel:
        return ref_paged_prefill_attention(q, k_pages, v_pages, block_table,
                                           q_start, new_lens, softcap=softcap)
    return _paged_prefill(q, k_pages, v_pages, block_table, q_start, new_lens,
                          softcap=softcap, interpret=INTERPRET)
