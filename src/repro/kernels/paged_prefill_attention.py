"""Paged chunked-prefill flash attention Pallas kernel (TPU target).

The serving engine's prefill hot-spot on the batched paged path: each
row's chunk of Sq queries (global positions q_start[b]..q_start[b]+
new_lens[b]) attends causally over that row's block-table-indexed pages —
the chunk's own K/V have already been scattered into the pages, so the
kernel reads context exclusively through the table. This replaces the
gather-pages-then-dense-mha materialization: attention traffic scales
with the table width the caller passes (length-bucketed by the executor)
instead of the context cap.

Grid: (B, max_pages) — page axis innermost, same scalar-prefetch pattern
as the decode kernel (``paged_attention``): the block table, per-row
``q_start`` and ``new_lens`` ride in SMEM so the BlockSpec index_map can
stage exactly the needed K/V page HBM→VMEM per step. Online softmax
across key pages with the (Sq, KV, G, hd) accumulator in VMEM scratch;
per-row causal masking against the ragged ``q_start``/``new_lens``
vectors. Steps past a row's last live page are predicated off AND their
index_map is clamped to the last valid page, so masked steps restage a
resident page instead of DMAing a fresh one.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(block_table, q_start, new_lens, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, page_size: int, max_pages: int,
            softcap: float, sm_scale: float):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    total = q_start[b] + new_lens[b]
    n_pages = (total + page_size - 1) // page_size

    @pl.when(p < n_pages)
    def _step():
        q = q_ref[0].astype(jnp.float32)          # (Sq, KV, G, hd)
        k = k_ref[0].astype(jnp.float32)          # (page_size, KV, hd)
        v = v_ref[0].astype(jnp.float32)

        s = jnp.einsum("skgd,tkd->skgt", q, k) * sm_scale  # (Sq, KV, G, T)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start[b] + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        tpos = p * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 3)
        # causal over history + chunk; the total clamp only matters for
        # padding queries (i >= new_lens), whose outputs are discarded —
        # it keeps them off stale page tails all the same
        s = jnp.where((tpos <= qpos) & (tpos < total), s, NEG_INF)

        m_prev = m_ref[...]                        # (Sq, KV, G, 1)
        m_cur = jnp.max(s, axis=3, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        pexp = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(pexp, axis=3, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jnp.einsum("skgt,tkd->skgd",
                                                         pexp, v)
        m_ref[...] = m_new

    @pl.when(p == max_pages - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype)


LANE = 128     # TPU lane width: last dim of every tile
SUBLANE = 8    # f32 sublane width: second-to-last dim


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_prefill_attention(q, k_pages, v_pages, block_table, q_start,
                            new_lens, *, softcap: float = 0.0,
                            interpret: bool = True):
    """q: (B,Sq,H,hd) right-padded chunks; k_pages/v_pages:
    (P,page_size,KV,hd) — already containing the chunk's K/V;
    block_table: (B,max_pages) int32; q_start: (B,) int32 context tokens
    before each chunk; new_lens: (B,) int32 valid chunk tokens (<= Sq).
    -> (B,Sq,H,hd); outputs at padding positions (i >= new_lens[b]) are
    exact zeros, matching ``ref_paged_prefill_attention``.

    Small ``head_dim``/``KV`` are zero-padded up to the TPU tile minima
    (lane 128 / sublane 8), exactly as in the decode kernel — zero
    padding is exact and ``sm_scale`` always uses the original head_dim.
    """
    B, Sq, H, hd = q.shape
    P, page_size, KV, _ = k_pages.shape
    max_pages = block_table.shape[1]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    orig_kv, orig_hd = KV, hd
    if hd % LANE or KV % SUBLANE:
        hd_p = _round_up(hd, LANE)
        kv_p = _round_up(KV, SUBLANE)
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, kv_p - KV), (0, 0),
                          (0, hd_p - hd)))
        k_pages = jnp.pad(
            k_pages, ((0, 0), (0, 0), (0, kv_p - KV), (0, hd_p - hd)))
        v_pages = jnp.pad(
            v_pages, ((0, 0), (0, 0), (0, kv_p - KV), (0, hd_p - hd)))
        KV, hd = kv_p, hd_p

    kernel = functools.partial(
        _kernel, page_size=page_size, max_pages=max_pages, softcap=softcap,
        sm_scale=1.0 / math.sqrt(orig_hd))

    def _kv_map(b, p, bt, qs, nl):
        # clamp padded grid steps to the row's last live page: the
        # @pl.when(p < n_pages) predicate discards the compute, and the
        # clamped index means the DMA restages an already-resident page
        # instead of streaming a fresh one per masked step
        last = jnp.maximum((qs[b] + nl[b] + page_size - 1) // page_size - 1,
                           0)
        return (bt[b, jnp.minimum(p, last)], 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, max_pages),
        in_specs=[
            pl.BlockSpec((1, Sq, KV, G, hd),
                         lambda b, p, bt, qs, nl: (b, 0, 0, 0, 0)),
            pl.BlockSpec((1, page_size, KV, hd), _kv_map),
            pl.BlockSpec((1, page_size, KV, hd), _kv_map),
        ],
        out_specs=pl.BlockSpec((1, Sq, KV, G, hd),
                               lambda b, p, bt, qs, nl: (b, 0, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Sq, KV, G, 1), jnp.float32),
            pltpu.VMEM((Sq, KV, G, 1), jnp.float32),
            pltpu.VMEM((Sq, KV, G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Sq, KV, G, hd), q.dtype),
        interpret=interpret,
    )(block_table, q_start, new_lens, qg, k_pages, v_pages)
    out = out[:, :, :orig_kv, :, :orig_hd]
    # padding queries (and whole padding rows): exact zeros, like the ref
    pad = jnp.arange(Sq, dtype=jnp.int32)[None, :] < new_lens[:, None]
    out = jnp.where(pad[:, :, None, None, None], out, 0.0)
    return out.reshape(B, Sq, H, orig_hd)
