"""Paged decode attention Pallas kernel (TPU target).

The serving engine's decode hot-spot: one query token per sequence attends
over a block-table-indexed paged KV cache. TPU adaptation of vLLM's
PagedAttention (see DESIGN.md): pages are dense (num_pages, page_size, KV,
hd) arrays; the block table rides in scalar-prefetch SMEM so the BlockSpec
index_map can stage exactly the needed K/V page HBM->VMEM per grid step.

Grid: (B, max_pages) — page axis innermost; online softmax across pages with
the (KV, G, hd) accumulator in VMEM scratch (G = query heads per KV head).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(block_table, lengths, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, page_size: int, max_pages: int,
            softcap: float, sm_scale: float):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths[b]
    n_pages = (length + page_size - 1) // page_size

    @pl.when(p < n_pages)
    def _step():
        q = q_ref[0].astype(jnp.float32)          # (KV, G, hd)
        k = k_ref[0].astype(jnp.float32)          # (page_size, KV, hd)
        v = v_ref[0].astype(jnp.float32)
        KV, G, hd = q.shape

        s = jnp.einsum("kgd,tkd->kgt", q, k) * sm_scale      # (KV, G, T)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        tpos = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 2)
        s = jnp.where(tpos < length, s, NEG_INF)

        m_prev = m_ref[...]                        # (KV, G, 1)
        m_cur = jnp.max(s, axis=2, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        pexp = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(pexp, axis=2, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jnp.einsum("kgt,tkd->kgd", pexp, v)
        m_ref[...] = m_new

    @pl.when(p == max_pages - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype)


LANE = 128     # TPU lane width: last dim of every tile
SUBLANE = 8    # f32 sublane width: second-to-last dim


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_attention(q, k_pages, v_pages, block_table, lengths, *,
                    softcap: float = 0.0, interpret: bool = True):
    """q: (B,H,hd); k_pages/v_pages: (P,page_size,KV,hd);
    block_table: (B,max_pages) int32; lengths: (B,) int32. -> (B,H,hd).

    Small ``head_dim``/``KV`` are zero-padded up to the TPU tile minima
    (lane 128 / sublane 8) — required by Mosaic on the compiled path and
    applied on the interpret path too so it exercises the same block
    geometry. Zero-padding is exact (padded kv-heads carry zero q/k/v and
    are sliced off, zero head-dim columns contribute nothing to the dot
    products). ``sm_scale`` always uses the *original* head_dim.
    """
    B, H, hd = q.shape
    P, page_size, KV, _ = k_pages.shape
    max_pages = block_table.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    orig_kv, orig_hd = KV, hd
    if hd % LANE or KV % SUBLANE:
        hd_p = _round_up(hd, LANE)
        kv_p = _round_up(KV, SUBLANE)
        qg = jnp.pad(qg, ((0, 0), (0, kv_p - KV), (0, 0), (0, hd_p - hd)))
        k_pages = jnp.pad(
            k_pages, ((0, 0), (0, 0), (0, kv_p - KV), (0, hd_p - hd)))
        v_pages = jnp.pad(
            v_pages, ((0, 0), (0, 0), (0, kv_p - KV), (0, hd_p - hd)))
        KV, hd = kv_p, hd_p

    kernel = functools.partial(
        _kernel, page_size=page_size, max_pages=max_pages, softcap=softcap,
        sm_scale=1.0 / math.sqrt(orig_hd))

    def _kv_map(b, p, bt, ln):
        # grid steps past the row's live pages are predicated off by
        # @pl.when(p < n_pages), but the BlockSpec pipeline would still
        # stage bt[b, p] (a trash/padding page) HBM→VMEM every masked
        # step; clamping to the row's last valid page makes those steps
        # restage an already-resident page — a no-op DMA — instead
        last = jnp.maximum((ln[b] + page_size - 1) // page_size - 1, 0)
        return (bt[b, jnp.minimum(p, last)], 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, max_pages),
        in_specs=[
            pl.BlockSpec((1, KV, G, hd), lambda b, p, bt, ln: (b, 0, 0, 0)),
            pl.BlockSpec((1, page_size, KV, hd), _kv_map),
            pl.BlockSpec((1, page_size, KV, hd), _kv_map),
        ],
        out_specs=pl.BlockSpec((1, KV, G, hd), lambda b, p, bt, ln: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KV, G, 1), jnp.float32),
            pltpu.VMEM((KV, G, 1), jnp.float32),
            pltpu.VMEM((KV, G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(block_table, lengths, qg, k_pages, v_pages)
    out = out[:, :orig_kv, :, :orig_hd]
    # length-0 guard (padding rows in bucketed batches): the accumulator
    # never ran, so force exact zeros rather than 0/eps division noise.
    out = jnp.where(lengths[:, None, None, None] > 0, out, 0.0)
    return out.reshape(B, H, orig_hd)
