"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1].

64L, d_model=6144, 48 heads (GQA kv=8), d_ff=32768, vocab=131072,
MoE 8e top-2 on every layer; attention-logit softcap 30.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    arch_type="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    num_experts=8,
    experts_per_token=2,
    moe_every=1,
    logit_softcap=30.0,
    max_seq_len=8192,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=512, vocab_size=512, num_experts=4, max_seq_len=512)
