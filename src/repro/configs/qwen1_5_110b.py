"""qwen1.5-110b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B family].

80L, d_model=8192, 64 heads (GQA kv=8), d_ff=49152, vocab=152064.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    arch_type="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    rope_style="llama",
    rope_theta=1000000.0,
    qkv_bias=True,
    max_seq_len=32768,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=512, vocab_size=512, max_seq_len=512)
