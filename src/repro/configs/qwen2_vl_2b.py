"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].

28L, d_model=1536, 12 heads (GQA kv=2), d_ff=8960, vocab=151936.
Vision frontend (ViT + projector) is a STUB per the brief: ``input_specs``
provides precomputed patch embeddings of shape (B, mm_tokens, d_model).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    arch_type="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    rope_style="mrope",
    rope_theta=1000000.0,
    qkv_bias=True,
    mm_tokens=1024,
    max_seq_len=32768,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=512, vocab_size=512, mm_tokens=16, max_seq_len=512)
