"""whisper-base [audio] — encoder-decoder, conv frontend stubbed
[arXiv:2212.04356].

6L decoder (+6L encoder), d_model=512, 8 heads, d_ff=2048, vocab=51865.
Mel-spectrogram + conv feature extractor is a STUB per the brief:
``input_specs`` provides precomputed frame embeddings (B, 1500, 512).
Positions are sinusoidal (adaptation note in DESIGN.md: whisper's learned
decoder table is replaced so the assigned 32k decode shape is representable).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    arch_type="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    rope_style="none",
    is_encoder_decoder=True,
    num_encoder_layers=6,
    encoder_seq=1500,
    max_target_positions=448,
    max_seq_len=32768,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, num_encoder_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, d_ff=256, vocab_size=512, encoder_seq=32,
        max_seq_len=128)
