"""gemma3-27b [dense] — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt family].

62L, d_model=5376, 32 heads (GQA kv=16), d_ff=21504, vocab=262144.
head_dim=128 (model card). Sliding window 1024 on local layers; every 6th
layer is global.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    arch_type="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    rope_style="llama",
    rope_theta=1000000.0,
    sliding_window=1024,
    local_global_period=6,
    max_seq_len=1048576,
)


def reduced() -> ModelConfig:
    # pattern [attn_l, attn]: one local + one global layer
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512, sliding_window=64,
        local_global_period=2, max_seq_len=512)
