"""deepseek-coder-33b [dense] — llama-arch [arXiv:2401.14196].

62L, d_model=7168, 56 heads (GQA kv=8), d_ff=19200, vocab=32256.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    arch_type="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=19200,
    vocab_size=32256,
    rope_style="llama",
    rope_theta=100000.0,
    max_seq_len=32768,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        d_ff=512, vocab_size=512, max_seq_len=512)
