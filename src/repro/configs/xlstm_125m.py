"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

12L, d_model=768, 4 heads, d_ff=0 (blocks carry their own projections),
vocab=50304. sLSTM at every 6th layer (offset 3), mLSTM elsewhere.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    arch_type="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    rope_style="none",
    slstm_every=6,
    slstm_offset=3,
    xlstm_proj_factor=2.0,
    tie_embeddings=True,
    max_seq_len=1048576,
)


def reduced() -> ModelConfig:
    # pattern [mlstm, slstm]
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
        vocab_size=512, slstm_every=2, slstm_offset=1, max_seq_len=512)
