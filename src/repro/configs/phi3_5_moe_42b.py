"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct].

32L, d_model=4096, 32 heads (GQA kv=8), d_ff=6400, vocab=32064.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    num_experts=16,
    experts_per_token=2,
    moe_every=1,
    max_seq_len=131072,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=512, vocab_size=512, num_experts=4, max_seq_len=512)
