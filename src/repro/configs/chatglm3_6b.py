"""chatglm3-6b [dense] — RoPE over half the head dim ("2d"), GQA
[arXiv:2406.12793].

28L, d_model=4096, 32 heads (GQA kv=2), d_ff=13696, vocab=65024.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    arch_type="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_style="half",
    qkv_bias=True,
    max_seq_len=32768,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=512, vocab_size=512, max_seq_len=512)
