"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE
[arXiv:2403.19887].

72L, d_model=8192, 64 heads (GQA kv=8), d_ff=24576, vocab=65536,
MoE 16 experts top-2 (every other layer). Attention at 1 of every 8 layers.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    moe_every=2,
    moe_offset=1,
    attn_every=8,
    attn_offset=4,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    max_seq_len=1048576,
)


def reduced() -> ModelConfig:
    # one mamba_moe + one attn layer: pattern [mamba_moe, attn]
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=512, vocab_size=512, num_experts=4, moe_every=2, moe_offset=0,
        attn_every=2, attn_offset=1, max_seq_len=512)
