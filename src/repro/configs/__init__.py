"""Assigned-architecture registry: ``get_config(arch_id)`` / ``get_reduced(arch_id)``.

Each module defines ``CONFIG`` (the exact assigned configuration, with the
source citation) and ``reduced()`` (a smoke-test variant of the same family:
<=2-ish layers covering one full block period, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "deepseek_coder_33b",
    "qwen2_vl_2b",
    "jamba_1_5_large_398b",
    "grok_1_314b",
    "phi3_5_moe_42b",
    "gemma3_27b",
    "chatglm3_6b",
    "xlstm_125m",
    "qwen1_5_110b",
    "whisper_base",
]

# CLI aliases (assignment spelling -> module)
ALIASES = {
    "deepseek-coder-33b": "deepseek_coder_33b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "grok-1-314b": "grok_1_314b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "gemma3-27b": "gemma3_27b",
    "chatglm3-6b": "chatglm3_6b",
    "xlstm-125m": "xlstm_125m",
    "qwen1.5-110b": "qwen1_5_110b",
    "whisper-base": "whisper_base",
}


def _module(arch: str):
    name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch: str):
    return _module(arch).CONFIG


def get_reduced(arch: str):
    return _module(arch).reduced()
