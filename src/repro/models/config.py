"""Unified model configuration covering all six assigned architecture families.

A config fully determines the layer *pattern* (which block type at which
depth) and the *stage* decomposition used to scan over stacked layer weights
(period detection keeps HLO size O(1) in depth — required to compile 80+
(arch x shape x mesh) dry-run programs on one CPU core).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax.numpy as jnp

# Block type names
ATTN = "attn"            # global causal attention + dense MLP
ATTN_L = "attn_l"        # sliding-window attention + dense MLP
ATTN_MOE = "attn_moe"    # global causal attention + MoE
MAMBA = "mamba"          # mamba block + dense MLP
MAMBA_MOE = "mamba_moe"  # mamba block + MoE
MLSTM = "mlstm"          # xLSTM matrix-memory block
SLSTM = "slstm"          # xLSTM scalar-memory block
ENC_ATTN = "enc_attn"    # bidirectional encoder attention + MLP
DEC_ATTN = "dec_attn"    # causal self-attn + cross-attn + MLP

ATTN_BLOCKS = {ATTN, ATTN_L, ATTN_MOE, ENC_ATTN, DEC_ATTN}
SSM_BLOCKS = {MAMBA, MAMBA_MOE, MLSTM, SLSTM}
MOE_BLOCKS = {ATTN_MOE, MAMBA_MOE}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 0       # MoE MLP at layers i with i % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25

    # --- attention variants ---
    rope_style: str = "llama"   # llama | mrope | half | none
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    sliding_window: int = 0      # window size for ATTN_L blocks
    local_global_period: int = 0 # gemma3: (period-1) local then 1 global
    logit_softcap: float = 0.0   # grok/gemma style attn logit soft-capping

    # --- hybrid (jamba) ---
    attn_every: int = 0    # attention at layers i with i % attn_every == attn_offset
    attn_offset: int = 0
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # --- xLSTM ---
    slstm_every: int = 0   # sLSTM at layers i with i % slstm_every == slstm_offset
    slstm_offset: int = 0
    xlstm_proj_factor: float = 2.0

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 1500     # stub conv-frontend output frames
    max_target_positions: int = 448

    # --- multimodal stub ---
    mm_tokens: int = 0          # stub patch/frame embedding tokens per request

    # --- misc ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    max_seq_len: int = 131072
    dtype: Any = jnp.bfloat16

    # set by pad_for_tp for the dry-run; 0 = unpadded
    orig_num_heads: int = 0
    orig_num_kv_heads: int = 0

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    def is_moe_layer(self, i: int) -> bool:
        return self.num_experts > 0 and self.moe_every > 0 and i % self.moe_every == self.moe_offset

    def block_type(self, i: int) -> str:
        """Block type for decoder layer i."""
        if self.arch_type == "ssm" and self.slstm_every >= 0 and self.d_ff == 0:
            if self.slstm_every > 0 and i % self.slstm_every == self.slstm_offset:
                return SLSTM
            return MLSTM
        if self.attn_every > 0:  # hybrid: attention only at some layers
            is_attn = i % self.attn_every == self.attn_offset
            moe = self.is_moe_layer(i)
            if is_attn:
                return ATTN_MOE if moe else ATTN
            return MAMBA_MOE if moe else MAMBA
        if self.is_encoder_decoder:
            return DEC_ATTN
        moe = self.is_moe_layer(i)
        if moe:
            return ATTN_MOE
        if self.local_global_period > 0:
            return ATTN if (i + 1) % self.local_global_period == 0 else ATTN_L
        if self.sliding_window > 0 and self.local_global_period == 0:
            return ATTN_L
        return ATTN

    def pattern(self) -> tuple[str, ...]:
        return tuple(self.block_type(i) for i in range(self.num_layers))

    def encoder_pattern(self) -> tuple[str, ...]:
        return tuple(ENC_ATTN for _ in range(self.num_encoder_layers))

    def stages(self) -> list[tuple[tuple[str, ...], int]]:
        """Decompose the decoder pattern into (period, repeats) stages."""
        return decompose_stages(self.pattern())

    def is_global_attn(self, block: str) -> bool:
        return block in (ATTN, ATTN_MOE, DEC_ATTN, ENC_ATTN)

    def window_for(self, block: str) -> int:
        return self.sliding_window if block == ATTN_L else 0

    def has_cross_attn(self, block: str) -> bool:
        return block == DEC_ATTN

    def norm_style(self) -> str:
        return "layernorm" if self.is_encoder_decoder else "rmsnorm"


def decompose_stages(pattern: tuple[str, ...]) -> list[tuple[tuple[str, ...], int]]:
    """Find the smallest period p such that pattern tiles by p, with remainder.

    Returns stages [(period_blocks, repeats), (remainder_blocks, 1)?].
    """
    n = len(pattern)
    if n == 0:
        return []
    for p in range(1, n + 1):
        reps = n // p
        if reps >= 1 and pattern[: p * reps] == pattern[:p] * reps:
            rem = pattern[p * reps:]
            # require the periodic part to actually cover the prefix
            if all(pattern[i] == pattern[i % p] for i in range(p * reps)):
                stages = [(pattern[:p], reps)]
                if rem:
                    stages.append((rem, 1))
                return stages
    return [(pattern, 1)]


def pad_for_tp(cfg: ModelConfig, tp: int) -> ModelConfig:
    """Pad query heads / replicate KV heads to a multiple of the TP degree.

    Standard practice (vLLM/MaxText require divisibility); the inflation is
    accounted in the roofline useful-FLOPs ratio.
    """
    def up(x: int) -> int:
        return ((x + tp - 1) // tp) * tp

    nh, nkv, nv = cfg.num_heads, cfg.num_kv_heads, cfg.vocab_size
    new_h, new_kv, new_v = up(nh), up(nkv), up(nv)
    if (new_h, new_kv, new_v) == (nh, nkv, nv):
        return cfg
    return dataclasses.replace(
        cfg,
        num_heads=new_h,
        num_kv_heads=new_kv,
        vocab_size=new_v,  # MaxText-style vocab padding for TP lm_head
        head_dim=cfg.hd,   # freeze head_dim so padding doesn't change it
        orig_num_heads=nh,
        orig_num_kv_heads=nkv,
    )
