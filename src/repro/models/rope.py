"""Rotary position embedding variants.

llama  — standard RoPE over the full head dim (deepseek, gemma, qwen, grok...)
half   — rotary over the first half of the head dim (ChatGLM3 "2d" RoPE)
mrope  — multimodal 3-section RoPE (temporal/height/width) from Qwen2-VL
none   — no rotary (whisper uses learned absolute positions)
"""
from __future__ import annotations

import jax.numpy as jnp


def _rotate_half(x):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([-x2, x1], axis=-1)


def _angles(positions, dim: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, dim//2)."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions[..., None].astype(jnp.float32) * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def _apply(x, cos, sin):
    # x: (B,S,H,D); cos/sin: (B,S,Dh) with Dh = D//2
    cos = jnp.concatenate([cos, cos], axis=-1)[:, :, None, :]
    sin = jnp.concatenate([sin, sin], axis=-1)[:, :, None, :]
    return (x.astype(jnp.float32) * cos + _rotate_half(x.astype(jnp.float32)) * sin).astype(x.dtype)


def apply_rope(x, positions, theta: float = 10000.0, style: str = "llama",
               mrope_sections=(2, 3, 3)):
    """Apply rotary embedding.

    x: (B, S, H, D). positions: (B, S) int32, or (B, S, 3) for mrope.
    mrope_sections: relative weights of the t/h/w sections (scaled to D//2).
    """
    if style == "none":
        return x
    D = x.shape[-1]
    if style == "llama":
        cos, sin = _angles(positions, D, theta)
        return _apply(x, cos, sin)
    if style == "half":
        # rotary on the first half of the head dim only (ChatGLM)
        d2 = D // 2
        xr, xp = x[..., :d2], x[..., d2:]
        cos, sin = _angles(positions, d2, theta)
        return jnp.concatenate([_apply(xr, cos, sin), xp], axis=-1)
    if style == "mrope":
        # positions (B,S,3): temporal, height, width streams; each section of
        # the frequency spectrum takes its angles from one stream.
        if positions.ndim == 2:
            positions = jnp.repeat(positions[..., None], 3, axis=-1)
        half = D // 2
        total = sum(mrope_sections)
        sizes = [half * s // total for s in mrope_sections]
        sizes[-1] = half - sum(sizes[:-1])
        cos_full, sin_full = _angles(
            jnp.moveaxis(positions, -1, 0), D, theta
        )  # (3, B, S, half)
        parts_c, parts_s = [], []
        off = 0
        for sec, sz in enumerate(sizes):
            parts_c.append(cos_full[sec, ..., off:off + sz])
            parts_s.append(sin_full[sec, ..., off:off + sz])
            off += sz
        cos = jnp.concatenate(parts_c, axis=-1)
        sin = jnp.concatenate(parts_s, axis=-1)
        return _apply(x, cos, sin)
    raise ValueError(f"unknown rope style {style!r}")
