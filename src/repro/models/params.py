"""Parameter declaration system.

Models declare parameters as trees of :class:`ParamDecl` — shape + logical
axis names + initializer. From one declaration tree we derive:

  * materialized parameters (``init_params``),
  * ``jax.ShapeDtypeStruct`` stand-ins for dry-run lowering (``abstract_params``),
  * ``PartitionSpec`` trees via logical-axis rules (``param_pspecs``).

Keeping a single source of truth for shapes and sharding is what lets the
multi-pod dry-run cover every architecture without per-arch sharding code.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    """Declaration of a single parameter tensor."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]  # logical axis name per dim (None = replicated)
    init: str = "normal"  # normal | zeros | ones | fill
    scale: float | None = None  # stddev; default fan-in
    dtype: Any = jnp.float32
    fill: float = 0.0  # used when init == "fill"

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} / axes {self.axes} mismatch")


def decl(shape, axes, init="normal", scale=None, dtype=jnp.float32,
         fill=0.0) -> ParamDecl:
    return ParamDecl(tuple(int(s) for s in shape), tuple(axes), init, scale,
                     dtype, fill)


def _is_decl(x) -> bool:
    return isinstance(x, ParamDecl)


def tree_map_decls(fn: Callable[[ParamDecl], Any], decls):
    return jax.tree.map(fn, decls, is_leaf=_is_decl)


def init_params(decls, key: jax.Array, dtype=None):
    """Materialize a declaration tree into actual arrays."""
    leaves, treedef = jax.tree.flatten(decls, is_leaf=_is_decl)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        dt = dtype or d.dtype
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dt))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dt))
        elif d.init == "fill":
            out.append(jnp.full(d.shape, d.fill, dt))
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            scale = d.scale if d.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, d.shape, jnp.float32) * scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


def abstract_params(decls, dtype=None):
    """ShapeDtypeStruct tree for .lower() — no allocation."""
    return tree_map_decls(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype or d.dtype), decls
    )


def param_count(decls) -> int:
    return sum(int(np.prod(d.shape)) for d in jax.tree.leaves(decls, is_leaf=_is_decl))


def param_bytes(decls, dtype_bytes=2) -> int:
    return param_count(decls) * dtype_bytes


def logical_to_pspec(axes: tuple[str | None, ...], rules: dict[str, Any]) -> PartitionSpec:
    """Map logical axis names to mesh axes via rules (MaxText-style)."""
    entries = []
    used: set[str] = set()
    for name in axes:
        mesh_ax = rules.get(name) if name is not None else None
        # one mesh axis may only appear once in a PartitionSpec
        if mesh_ax is not None:
            flat = (mesh_ax,) if isinstance(mesh_ax, str) else tuple(mesh_ax)
            if any(m in used for m in flat):
                mesh_ax = None
            else:
                used.update(flat)
        entries.append(mesh_ax)
    return PartitionSpec(*entries)


def param_pspecs(decls, rules: dict[str, Any]):
    return tree_map_decls(lambda d: logical_to_pspec(d.axes, rules), decls)
