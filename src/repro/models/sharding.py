"""Logical-axis sharding rules + activation sharding context.

Rules map *logical* axis names (used in ParamDecls and activation
annotations) to physical mesh axis names. The dry-run launcher installs a
rule set + mesh via :func:`use_rules`; on single-device CPU (tests, smoke
runs) no rules are installed and every annotation is a no-op.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax
from jax.sharding import PartitionSpec

from .params import logical_to_pspec

# Baseline rule sets -------------------------------------------------------

# Training: batch over data(+pod), TP over model, FSDP(ZeRO-3-ish) of params
# over data on the embed dim.
TRAIN_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": "data",          # FSDP shard of params along d_model
    "embed_act": None,         # activations' d_model dim
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": None,
    "expert_mlp": "model",
    "vocab": "model",
    "conv": None,
    "state": None,
    "inner": "model",          # mamba/xlstm inner dim
}

# Serving (decode/prefill): no optimizer, params TP over model, replicated
# over data; batch over (pod, data).
SERVE_RULES: dict[str, Any] = {**TRAIN_RULES, "embed": None}

# Long-context decode, batch=1: KV-cache sequence dim context-parallel over
# data; batch replicated.
LONG_CTX_RULES: dict[str, Any] = {
    **SERVE_RULES,
    "batch": None,
    "cache_seq": "data",
}


class _Ctx(threading.local):
    def __init__(self):
        self.rules: dict[str, Any] | None = None
        self.mesh = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_rules(rules: dict[str, Any], mesh):
    prev = (_CTX.rules, _CTX.mesh)
    _CTX.rules, _CTX.mesh = rules, mesh
    try:
        yield
    finally:
        _CTX.rules, _CTX.mesh = prev


def current_rules() -> dict[str, Any] | None:
    return _CTX.rules


def shard_act(x, *axes: str | None):
    """Constrain activation sharding by logical axes; no-op without rules."""
    if _CTX.rules is None or _CTX.mesh is None:
        return x
    spec = logical_to_pspec(tuple(axes), _CTX.rules)
    ns = jax.sharding.NamedSharding(_CTX.mesh, spec)
    return jax.lax.with_sharding_constraint(x, ns)


def act_pspec(*axes: str | None) -> PartitionSpec:
    if _CTX.rules is None:
        return PartitionSpec()
    return logical_to_pspec(tuple(axes), _CTX.rules)
