"""Unified multi-family model: declaration, forward (train / prefill /
decode), and cache construction.

Layer stacking: the decoder pattern is decomposed into (period, repeats)
stages; per stage, weights are stacked on a leading ``layers`` dim and the
period body runs under ``jax.lax.scan``. The per-layer KV/SSM cache is
scanned as xs/ys. This keeps HLO size independent of depth — a requirement
for compiling 512-way SPMD programs for 80+ (arch x shape x mesh) combos on
one CPU core.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import layers as L
from .config import (ATTN, ATTN_L, ATTN_MOE, DEC_ATTN, ENC_ATTN, MAMBA,
                     MAMBA_MOE, MLSTM, MOE_BLOCKS, SLSTM, ModelConfig)
from .params import ParamDecl, decl, tree_map_decls
from .sharding import shard_act
from .ssm import mamba_block
from .xlstm import mlstm_block, slstm_block


def layer_norm(x, w, b, eps=1e-6):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(x.dtype)


def _norm(x, p, cfg: ModelConfig, key: str):
    if cfg.norm_style() == "layernorm":
        return layer_norm(x, p[key], p[key + "_b"], cfg.norm_eps)
    return L.rms_norm(x, p[key], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Parameter declarations
# ---------------------------------------------------------------------------

def _attn_decls(cfg: ModelConfig, cross: bool = False) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    d = {
        "wq": decl((D, H, hd), ("embed", "heads", "head_dim")),
        "wk": decl((D, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wv": decl((D, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wo": decl((H, hd, D), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias and not cross:
        d.update({
            "bq": decl((H, hd), ("heads", "head_dim"), init="zeros"),
            "bk": decl((KV, hd), ("kv_heads", "head_dim"), init="zeros"),
            "bv": decl((KV, hd), ("kv_heads", "head_dim"), init="zeros"),
        })
    return d


def _norm_decl(cfg: ModelConfig, name: str) -> dict:
    d = {name: decl((cfg.d_model,), ("embed",),
                    init="zeros" if cfg.norm_style() == "rmsnorm" else "ones")}
    if cfg.norm_style() == "layernorm":
        d[name + "_b"] = decl((cfg.d_model,), ("embed",), init="zeros")
    return d


def _mlp_decls(cfg: ModelConfig) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    if cfg.is_encoder_decoder:  # whisper: 2-matrix GELU MLP
        return {"wi": decl((D, F), ("embed", "mlp")),
                "wo": decl((F, D), ("mlp", "embed"))}
    return {"wi": decl((D, F), ("embed", "mlp")),
            "wg": decl((D, F), ("embed", "mlp")),
            "wo": decl((F, D), ("mlp", "embed"))}


def _moe_decls(cfg: ModelConfig) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": decl((D, E), ("embed", None), scale=0.02),
        "wi": decl((E, D, F), ("experts", "embed", "expert_mlp")),
        "wg": decl((E, D, F), ("experts", "embed", "expert_mlp")),
        "wo": decl((E, F, D), ("experts", "expert_mlp", "embed")),
    }


def _mamba_decls(cfg: ModelConfig) -> dict:
    D, DI, N, KC = cfg.d_model, cfg.d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    R = max(16, math.ceil(D / 16))
    return {
        "in_proj": decl((D, 2 * DI), ("embed", "inner")),
        "conv_w": decl((KC, DI), (None, "inner"), scale=0.5),
        "conv_b": decl((DI,), ("inner",), init="zeros"),
        "dt_down": decl((DI, R), ("inner", None)),
        "dt_up": decl((R, DI), (None, "inner")),
        "dt_bias": decl((DI,), ("inner",), init="zeros"),
        "wB": decl((DI, N), ("inner", "state")),
        "wC": decl((DI, N), ("inner", "state")),
        "A_log": decl((DI, N), ("inner", "state"), init="zeros"),
        "D_skip": decl((DI,), ("inner",), init="ones"),
        "out_proj": decl((DI, D), ("inner", "embed")),
    }


def _mlstm_decls(cfg: ModelConfig) -> dict:
    D, H = cfg.d_model, cfg.num_heads
    DI = int(cfg.xlstm_proj_factor * D)
    return {
        "up_proj": decl((D, 2 * DI), ("embed", "inner")),
        "wq": decl((DI, DI), ("inner", None)),
        "bq": decl((DI,), (None,), init="zeros"),
        "wk": decl((DI, DI), ("inner", None)),
        "bk": decl((DI,), (None,), init="zeros"),
        "wv": decl((DI, DI), ("inner", None)),
        "bv": decl((DI,), (None,), init="zeros"),
        "wi_g": decl((DI, H), ("inner", None), scale=0.02),
        "bi_g": decl((H,), (None,), init="zeros"),
        "wf_g": decl((DI, H), ("inner", None), scale=0.02),
        "bf_g": decl((H,), (None,), init="ones"),
        "down_proj": decl((DI, D), ("inner", "embed")),
    }


def _slstm_decls(cfg: ModelConfig) -> dict:
    D, H = cfg.d_model, cfg.num_heads
    hd = D // H
    F = int(4 * D / 3)
    d = {}
    for g in ("z", "i", "f", "o"):
        d["W" + g] = decl((H, hd, hd), ("heads", None, None))
        d["b" + g] = decl((H, hd), ("heads", None),
                          init="ones" if g == "f" else "zeros")
        d["R" + g] = decl((H, hd, hd), ("heads", None, None), scale=0.02)
    d["ff_up"] = decl((D, F), ("embed", "mlp"))
    d["ff_gate"] = decl((D, F), ("embed", "mlp"))
    d["ff_down"] = decl((F, D), ("mlp", "embed"))
    return d


def block_decls(cfg: ModelConfig, bt: str) -> dict:
    """Namespaced decl tree for one block: {'attn': {...}, 'mlp': {...}, ...}."""
    d = dict(_norm_decl(cfg, "ln1"))
    if bt in (ATTN, ATTN_L, ATTN_MOE, ENC_ATTN, DEC_ATTN):
        d["attn"] = _attn_decls(cfg)
        d.update(_norm_decl(cfg, "ln2"))
        if bt == DEC_ATTN:
            d["cross"] = _attn_decls(cfg, cross=True)
            d.update(_norm_decl(cfg, "ln_x"))
        if bt in MOE_BLOCKS:
            d["moe"] = _moe_decls(cfg)
        else:
            d["mlp"] = _mlp_decls(cfg)
    elif bt in (MAMBA, MAMBA_MOE):
        d["mamba"] = _mamba_decls(cfg)
        d.update(_norm_decl(cfg, "ln2"))
        if bt in MOE_BLOCKS:
            d["moe"] = _moe_decls(cfg)
        else:
            d["mlp"] = _mlp_decls(cfg)
    elif bt == MLSTM:
        d["core"] = _mlstm_decls(cfg)
    elif bt == SLSTM:
        d["core"] = _slstm_decls(cfg)
    else:
        raise ValueError(bt)
    return d


def _stack(d: dict, reps: int) -> dict:
    return tree_map_decls(
        lambda p: ParamDecl((reps,) + p.shape, ("layers",) + p.axes, p.init,
                            p.scale, p.dtype), d)


def model_decls(cfg: ModelConfig) -> dict:
    D, V = cfg.d_model, cfg.vocab_size
    out = {
        "embed": decl((V, D), ("vocab", "embed"), scale=0.02),
        "stages": [
            {f"b{i}": _stack(block_decls(cfg, bt), reps)
             for i, bt in enumerate(period)}
            for period, reps in cfg.stages()
        ],
    }
    out.update(_norm_decl(cfg, "final_norm"))
    if not cfg.tie_embeddings:
        out["lm_head"] = decl((D, V), ("embed", "vocab"), scale=0.02)
    if cfg.is_encoder_decoder:
        out["enc_stages"] = [
            {"b0": _stack(block_decls(cfg, ENC_ATTN), cfg.num_encoder_layers)}
        ]
        out.update({k + "_enc": v for k, v in _norm_decl(cfg, "final_norm").items()})
    return out


# ---------------------------------------------------------------------------
# Cache declarations
# ---------------------------------------------------------------------------

def cache_decls(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16, window_cache: bool = False) -> dict:
    """Decl tree for the decode/prefill cache (dense JetStream-style layout)."""
    KV, hd = cfg.num_kv_heads, cfg.hd
    H = cfg.num_heads

    def attn_cache(bt):
        S = max_len
        if window_cache and bt == ATTN_L and cfg.sliding_window > 0:
            S = min(max_len, cfg.sliding_window)
        d = {
            "k": decl((batch, S, KV, hd), ("batch", "cache_seq", "kv_heads", None),
                      init="zeros", dtype=dtype),
            "v": decl((batch, S, KV, hd), ("batch", "cache_seq", "kv_heads", None),
                      init="zeros", dtype=dtype),
        }
        if dtype == jnp.int8:  # per-(token, head) quantization scales
            for s in ("k_scale", "v_scale"):
                d[s] = decl((batch, S, KV, 1),
                            ("batch", "cache_seq", "kv_heads", None),
                            init="ones", dtype=jnp.float32)
        if bt == DEC_ATTN:
            cross_dt = jnp.bfloat16 if dtype == jnp.int8 else dtype
            d["ck"] = decl((batch, cfg.encoder_seq, KV, hd),
                           ("batch", None, "kv_heads", None), init="zeros",
                           dtype=cross_dt)
            d["cv"] = decl((batch, cfg.encoder_seq, KV, hd),
                           ("batch", None, "kv_heads", None), init="zeros",
                           dtype=cross_dt)
        return d

    def block_cache(bt):
        if bt in (ATTN, ATTN_L, ATTN_MOE, DEC_ATTN):
            return attn_cache(bt)
        if bt in (MAMBA, MAMBA_MOE):
            return {
                "conv": decl((batch, cfg.mamba_d_conv - 1, cfg.d_inner),
                             ("batch", None, "inner"), init="zeros", dtype=dtype),
                "ssm": decl((batch, cfg.d_inner, cfg.mamba_d_state),
                            ("batch", "inner", "state"), init="zeros",
                            dtype=jnp.float32),
            }
        if bt == MLSTM:
            DI = int(cfg.xlstm_proj_factor * cfg.d_model)
            hdi = DI // H
            return {
                "C": decl((batch, H, hdi, hdi), ("batch", "heads", None, None),
                          init="zeros", dtype=jnp.float32),
                "n": decl((batch, H, hdi), ("batch", "heads", None),
                          init="zeros", dtype=jnp.float32),
                "m": decl((batch, H), ("batch", "heads"), init="fill",
                          fill=-1e30, dtype=jnp.float32),
            }
        if bt == SLSTM:
            hds = cfg.d_model // H
            return {k: decl((batch, H, hds), ("batch", "heads", None),
                            init="ones" if k == "n" else "zeros",
                            dtype=jnp.float32)
                    for k in ("c", "n", "m", "h")}
        raise ValueError(bt)

    return {
        "stages": [
            {f"b{i}": _stack(block_cache(bt), reps) for i, bt in enumerate(period)}
            for period, reps in cfg.stages()
        ],
        "idx": decl((), (), init="zeros", dtype=jnp.int32),
    }


def paged_supported(cfg: ModelConfig) -> bool:
    """True when every decoder block can use the paged-KV cache protocol
    (global causal attention, optionally MoE). SSM/xLSTM state and
    sliding-window / cross-attention KV keep the dense slot cache — their
    per-request footprint is constant or windowed, not paged."""
    if cfg.is_encoder_decoder:
        return False
    return all(bt in (ATTN, ATTN_MOE)
               for period, _ in cfg.stages() for bt in period)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _apply_attnish(x, bp, bt, cfg, *, positions, q_start, cache, enc_out, idx,
                   paged_ctx=None, attn_impl="gather"):
    """Attention-family block (incl. MoE MLP and cross-attn). Returns
    (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = _norm(x, bp, cfg, "ln1")
    if paged_ctx is not None:
        # batched paged-KV serving path: cache is the whole flat
        # PagedStackStore (scan carry); block table / ragged lengths /
        # this step's layer index ride in paged_ctx (see DESIGN.md
        # §Batched execution path). Sliding-window
        # and cross-attention blocks keep the dense slot cache — the
        # executor gates which archs take this path.
        if bt not in (ATTN, ATTN_MOE):
            raise NotImplementedError(
                f"paged cache protocol does not support block type {bt!r}")
        attn_out, new_cache = L.paged_attention_block(
            h, bp["attn"], cfg, positions=positions, store=cache,
            ctx=paged_ctx, impl=attn_impl)
        x = x + attn_out
        h = _norm(x, bp, cfg, "ln2")
        if bt in MOE_BLOCKS:
            mlp_out, aux = L.moe_block(h, bp["moe"], cfg)
        else:
            mlp_out = L.mlp_block(h, bp["mlp"])
        return x + mlp_out, new_cache, aux
    window = cfg.window_for(bt)
    blk_cache = None
    if cache is not None and bt != ENC_ATTN:
        blk_cache = {k: cache[k] for k in ("k", "v", "k_scale", "v_scale")
                     if k in cache}
        blk_cache["idx"] = idx
    attn_out, new_kv = L.attention_block(
        h, bp["attn"], cfg, positions=positions, q_start=q_start, window=window,
        cache=blk_cache, is_causal=(bt != ENC_ATTN))
    x = x + attn_out
    new_cache = dict(cache) if cache is not None else None
    if new_kv is not None:
        for k in ("k", "v", "k_scale", "v_scale"):
            if k in new_kv:
                new_cache[k] = new_kv[k]

    if bt == DEC_ATTN:
        h = _norm(x, bp, cfg, "ln_x")
        cp = bp["cross"]
        if cache is not None and enc_out is None:
            kv = (L._maybe_dequant(cache["ck"], x.dtype),
                  L._maybe_dequant(cache["cv"], x.dtype))  # cached cross kv
        else:
            ck = jnp.einsum("btd,dhk->bthk", enc_out, cp["wk"])
            cv = jnp.einsum("btd,dhk->bthk", enc_out, cp["wv"])
            kv = (ck, cv)
            if new_cache is not None:
                new_cache["ck"] = ck.astype(new_cache["ck"].dtype)
                new_cache["cv"] = cv.astype(new_cache["cv"].dtype)
        q = jnp.einsum("bsd,dhk->bshk", h, cp["wq"])
        Tk = kv[0].shape[1]
        mask = jnp.ones((h.shape[1], Tk), bool)
        out = L.mha(q, kv[0].astype(q.dtype), kv[1].astype(q.dtype),
                    mask[None, None], softcap=0.0)
        x = x + jnp.einsum("bshk,hkd->bsd", out, cp["wo"])

    h = _norm(x, bp, cfg, "ln2")
    if bt in MOE_BLOCKS:
        mlp_out, aux = L.moe_block(h, bp["moe"], cfg)
    elif cfg.is_encoder_decoder:
        mp = bp["mlp"]
        mlp_out = jnp.einsum(
            "bsf,fd->bsd", jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, mp["wi"])),
            mp["wo"])
    else:
        mlp_out = L.mlp_block(h, bp["mlp"])
    return x + mlp_out, new_cache, aux


def _apply_mambaish(x, bp, bt, cfg, *, cache):
    aux = jnp.zeros((), jnp.float32)
    h = _norm(x, bp, cfg, "ln1")
    m_cache = None
    if cache is not None:
        m_cache = {"conv": cache["conv"], "ssm": cache["ssm"]}
    out, new_m = mamba_block(h, bp["mamba"], cfg, cache=m_cache)
    x = x + out
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_m["conv"].astype(cache["conv"].dtype),
                     "ssm": new_m["ssm"].astype(cache["ssm"].dtype)}
    h = _norm(x, bp, cfg, "ln2")
    if bt in MOE_BLOCKS:
        mlp_out, aux = L.moe_block(h, bp["moe"], cfg)
    else:
        mlp_out = L.mlp_block(h, bp["mlp"])
    return x + mlp_out, new_cache, aux


def apply_block(x, bp, bt, cfg, *, positions, q_start, cache, enc_out, idx,
                paged_ctx=None, attn_impl="gather"):
    if bt in (ATTN, ATTN_L, ATTN_MOE, ENC_ATTN, DEC_ATTN):
        return _apply_attnish(x, bp, bt, cfg, positions=positions,
                              q_start=q_start, cache=cache, enc_out=enc_out,
                              idx=idx, paged_ctx=paged_ctx,
                              attn_impl=attn_impl)
    if bt in (MAMBA, MAMBA_MOE):
        return _apply_mambaish(x, bp, bt, cfg, cache=cache)
    if bt == MLSTM:
        h = _norm(x, bp, cfg, "ln1")
        out, new_c = mlstm_block(h, bp["core"], cfg, cache=cache)
        new_cache = None
        if cache is not None:
            new_cache = {k: new_c[k].astype(cache[k].dtype) for k in cache}
        return x + out, new_cache, jnp.zeros((), jnp.float32)
    if bt == SLSTM:
        h = _norm(x, bp, cfg, "ln1")
        out, new_c = slstm_block(h, bp["core"], cfg, cache=cache)
        new_cache = None
        if cache is not None:
            new_cache = {k: new_c[k].astype(cache[k].dtype) for k in cache}
        return x + out, new_cache, jnp.zeros((), jnp.float32)
    raise ValueError(bt)


def _run_stages(x, stage_params, stage_caches, patternized, cfg, *,
                positions, q_start, enc_out, idx, remat, paged_ctx=None,
                attn_impl="gather"):
    """Scan each stage's period body over its repeats."""
    total_aux = jnp.zeros((), jnp.float32)
    new_caches = []
    for si, (period, reps) in enumerate(patternized):
        sp = stage_params[si]
        sc = stage_caches[si] if stage_caches is not None else None

        if paged_ctx is not None and sc is not None:
            # batched paged serving: the stage's {"b<i>": PagedStackStore}
            # stores ride the scan as *carry* (donated at the jit boundary
            # => XLA aliases them in place), and the per-step layer index
            # rides as xs to offset reads/writes into the flat page pool.
            # Consuming the stores as xs/ys here (the old layout) restacked
            # the whole page array every call — an O(store capacity) copy
            # per step that the carry layout eliminates.
            def paged_body(carry, per_layer, period=period):
                xx, aux, stores = carry
                lp, li = per_layer
                new_stores = {}
                for bi, bt in enumerate(period):
                    xx, ns, a = apply_block(
                        xx, lp[f"b{bi}"], bt, cfg, positions=positions,
                        q_start=q_start, cache=stores[f"b{bi}"],
                        enc_out=enc_out, idx=idx,
                        paged_ctx=dict(paged_ctx, layer=li),
                        attn_impl=attn_impl)
                    new_stores[f"b{bi}"] = ns
                    aux = aux + a
                return (xx, aux, new_stores), None

            (x, total_aux, nc), _ = jax.lax.scan(
                paged_body, (x, total_aux, sc),
                (sp, jnp.arange(reps, dtype=jnp.int32)))
            new_caches.append(nc)
            continue

        def body(carry, per_layer, period=period):
            xx, aux = carry
            lp, lc = per_layer
            new_lc = {} if lc is not None else None
            for bi, bt in enumerate(period):
                blk_c = lc[f"b{bi}"] if lc is not None else None
                xx, nbc, a = apply_block(
                    xx, lp[f"b{bi}"], bt, cfg, positions=positions,
                    q_start=q_start, cache=blk_c, enc_out=enc_out, idx=idx,
                    paged_ctx=paged_ctx, attn_impl=attn_impl)
                if new_lc is not None:
                    new_lc[f"b{bi}"] = nbc
                aux = aux + a
            return (xx, aux), new_lc

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        if sc is None:
            (x, total_aux), _ = jax.lax.scan(
                lambda c, p, period=period: (body(c, (p, None))[0], None),
                (x, total_aux), sp)
            new_caches.append(None)
        else:
            (x, total_aux), nc = jax.lax.scan(body, (x, total_aux), (sp, sc))
            new_caches.append(nc)
    return x, new_caches, total_aux


def encode(params, cfg: ModelConfig, frames):
    """Whisper encoder over stub conv-frontend frame embeddings (B,T,D)."""
    B, T, D = frames.shape
    pos = jnp.arange(T)
    x = frames + _sinusoid(T, D).astype(frames.dtype)
    x, _, _ = _run_stages(
        x, params["enc_stages"], None, [((ENC_ATTN,), cfg.num_encoder_layers)],
        cfg, positions=pos[None], q_start=0, enc_out=None, idx=None, remat=False)
    if cfg.norm_style() == "layernorm":
        x = layer_norm(x, params["final_norm_enc"], params["final_norm_b_enc"],
                       cfg.norm_eps)
    else:
        x = L.rms_norm(x, params["final_norm_enc"], cfg.norm_eps)
    return x


def _sinusoid(T, D):
    return _sinusoid_at(jnp.arange(T)[None], D)


def _sinusoid_at(positions, D):
    """positions (B,S) -> (B,S,D) sinusoidal embedding."""
    pos = positions.astype(jnp.float32)[..., None]
    dim = jnp.arange(0, D, 2, dtype=jnp.float32)
    ang = pos / jnp.power(10000.0, dim / D)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def forward(params, cfg: ModelConfig, tokens, *, positions=None,
            mm_embeds=None, enc_frames=None, cache=None, q_start=0,
            remat=False, last_only=False, last_pos=None, attn_impl="gather"):
    """Unified forward.

    tokens: (B, S) int32. positions: (B,S) or (B,S,3) for mrope.
    mm_embeds: (B, N_mm, D) stub patch/frame embeddings (VLM) — replace the
      first N_mm token embeddings.
    enc_frames: (B, T_enc, D) stub audio frames (whisper).
    cache: cache tree from cache_decls (prefill-with-cache / decode), or None
      — OR a *paged* cache for the batched serving path: a dict with
      "stages" (per-stage {"b<i>": PagedStackStore} — flat scan-carry
      stores, see cache.paged.PagedStore), "block_table" (B, max_pages),
      "lengths" (B,) context written per row, and "new_lens" (B,) valid
      new tokens per row. The presence of "block_table" selects the
      paged protocol: stores ride the layer scan as carry (donate them
      at the jit boundary for in-place updates) and the per-step layer
      index addresses the flat page pool; attn_impl ('gather' |
      'kernel') picks the decode attention backend (see
      layers.paged_attention_block).
    last_pos: (B,) int32 — gather this position per row before the lm_head
      (ragged packed prefill: only each row's last real token needs logits).
    Returns (logits (B,S,V), new_cache_or_None, aux_loss).
    """
    B, S = tokens.shape
    if positions is None:
        positions = q_start + jnp.arange(S)[None, :]
        positions = jnp.broadcast_to(positions, (B, S))
    # mixed precision: master params may be f32; compute in cfg.dtype
    params = jax.tree.map(lambda a: a.astype(cfg.dtype)
                          if a.dtype == jnp.float32 else a, params)
    x = params["embed"].astype(cfg.dtype)[tokens]
    if mm_embeds is not None:
        # stub patch/frame embeddings replace the first N_mm token embeds
        x = jax.lax.dynamic_update_slice(x, mm_embeds.astype(x.dtype), (0, 0, 0))
    if cfg.is_encoder_decoder:
        x = x + _sinusoid_at(positions[..., 0] if positions.ndim == 3 else positions,
                             cfg.d_model).astype(x.dtype)
    x = shard_act(x, "batch", "seq", "embed_act")

    enc_out = None
    if cfg.is_encoder_decoder and enc_frames is not None:
        enc_out = encode(params, cfg, enc_frames.astype(cfg.dtype))

    paged = cache is not None and "block_table" in cache
    paged_ctx = None
    if paged:
        idx = None
        paged_ctx = {"block_table": cache["block_table"],
                     "lengths": cache["lengths"],
                     "new_lens": cache["new_lens"]}
    else:
        idx = cache["idx"] if cache is not None else None
    stage_caches = cache["stages"] if cache is not None else None
    x, new_stage_caches, aux = _run_stages(
        x, params["stages"], stage_caches, cfg.stages(), cfg,
        positions=positions, q_start=q_start, enc_out=enc_out, idx=idx,
        remat=remat, paged_ctx=paged_ctx, attn_impl=attn_impl)

    if last_pos is not None:
        # packed ragged prefill: each row's last real position only
        x = jnp.take_along_axis(x, last_pos[:, None, None], axis=1)
    elif last_only:
        x = x[:, -1:]  # serving prefill: lm_head on the final position only
    if cfg.norm_style() == "layernorm":
        x = layer_norm(x, params["final_norm"], params["final_norm_b"], cfg.norm_eps)
    else:
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    logits = shard_act(logits, "batch", "seq", "vocab")

    new_cache = None
    if paged:
        new_cache = {"stages": new_stage_caches,
                     "block_table": cache["block_table"],
                     "lengths": cache["lengths"] + cache["new_lens"],
                     "new_lens": cache["new_lens"]}
    elif cache is not None:
        new_cache = {"stages": new_stage_caches, "idx": idx + S}
    return logits, new_cache, aux
