"""Mamba selective-SSM block (Jamba's recurrent layer), TPU-adapted.

The CUDA selective-scan kernel is replaced by a *chunked* linear-recurrence:
an outer ``lax.scan`` over sequence chunks carrying the (B, DI, N) state and
an inner ``associative_scan`` within each chunk. This keeps the materialized
state tensor at (B, Q, DI, N) for chunk size Q instead of (B, S, DI, N) —
the TPU-native equivalent of the paper's GPU kernel blocking (see DESIGN.md
hardware-adaptation notes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .sharding import shard_act


def _scan_chunk(a, b, h0):
    """Linear recurrence h_t = a_t * h_{t-1} + b_t within a chunk.

    a, b: (B, Q, DI, N); h0: (B, DI, N). Returns (h_all (B,Q,DI,N), h_last).
    """
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_c, b_c = jax.lax.associative_scan(combine, (a, b), axis=1)
    h_all = a_c * h0[:, None] + b_c
    return h_all, h_all[:, -1]


def mamba_block(x, p, cfg: ModelConfig, *, cache=None, chunk: int = 256):
    """x (B,S,D) -> (y (B,S,D), new_cache).

    cache (decode): {"conv": (B, d_conv-1, DI), "ssm": (B, DI, N)}.
    """
    B, S, D = x.shape
    DI, N, KC = cfg.d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])  # (B,S,2*DI)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = shard_act(xi, "batch", "seq", "inner")

    # causal depthwise conv, kernel KC
    w = p["conv_w"]  # (KC, DI)
    if cache is not None:
        prev = cache["conv"].astype(xi.dtype)  # (B, KC-1, DI)
        xpad = jnp.concatenate([prev, xi], axis=1)
        new_conv = xpad[:, -(KC - 1):]
    else:
        xpad = jnp.pad(xi, ((0, 0), (KC - 1, 0), (0, 0)))
        new_conv = xpad[:, -(KC - 1):]
    xc = sum(xpad[:, i:i + S] * w[i] for i in range(KC)) + p["conv_b"]
    xc = jax.nn.silu(xc)

    # SSM parameters (input-dependent)
    dt = jax.nn.softplus(jnp.einsum("bsi,ir->bsr", xc, p["dt_down"]) @ p["dt_up"]
                         + p["dt_bias"])                        # (B,S,DI)
    Bm = jnp.einsum("bsi,in->bsn", xc, p["wB"])                  # (B,S,N)
    Cm = jnp.einsum("bsi,in->bsn", xc, p["wC"])                  # (B,S,N)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (DI,N)

    dt32, xc32 = dt.astype(jnp.float32), xc.astype(jnp.float32)
    h0 = jnp.zeros((B, DI, N), jnp.float32) if cache is None else cache["ssm"].astype(jnp.float32)

    def chunk_terms(dt_c, B_c, x_c):
        a = jnp.exp(dt_c[..., None] * A)                         # (B,Q,DI,N)
        b = (dt_c * x_c)[..., None] * B_c[:, :, None, :].astype(jnp.float32)
        return a, b

    if S == 1:  # decode fast path
        a, b = chunk_terms(dt32, Bm, xc32)
        h = a[:, 0] * h0 + b[:, 0]
        y = jnp.einsum("bin,bn->bi", h, Cm[:, 0].astype(jnp.float32))[:, None]
        new_ssm = h
    else:
        Q = min(chunk, S)
        pad = (-S) % Q
        if pad:
            dt32 = jnp.pad(dt32, ((0, 0), (0, pad), (0, 0)))
            Bm_p = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
            xc32_p = jnp.pad(xc32, ((0, 0), (0, pad), (0, 0)))
        else:
            Bm_p, xc32_p = Bm, xc32
        nq = dt32.shape[1] // Q

        def outer(h, inputs):
            dt_c, B_c, x_c = inputs
            a, b = chunk_terms(dt_c, B_c, x_c)
            h_all, h_last = _scan_chunk(a, b, h)
            return h_last, h_all

        xs = (dt32.reshape(B, nq, Q, DI).swapaxes(0, 1),
              Bm_p.reshape(B, nq, Q, N).swapaxes(0, 1),
              xc32_p.reshape(B, nq, Q, DI).swapaxes(0, 1))
        h_last, h_seq = jax.lax.scan(outer, h0, xs)
        h_seq = h_seq.swapaxes(0, 1).reshape(B, nq * Q, DI, N)[:, :S]
        y = jnp.einsum("bsin,bsn->bsi", h_seq, Cm.astype(jnp.float32))
        new_ssm = h_last

    y = (y + xc32 * p["D_skip"].astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "ssm": new_ssm}
    else:
        new_cache = {"conv": new_conv, "ssm": new_ssm}
    return shard_act(out, "batch", "seq", "embed_act"), new_cache
