"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Faithful to arXiv:2405.04517 at the block level: exponential gating with
log-space stabilizer state m, per-head matrix memory C (mLSTM) / scalar
cell state c with block-diagonal recurrence (sLSTM). Sequence processing
uses ``lax.scan`` (single While loop in HLO — compile-friendly at 32k+).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .sharding import shard_act


def _mlstm_step(state, inp, eps=1e-6):
    """One mLSTM step. state: (C (B,H,d,d), n (B,H,d), m (B,H)).
    inp: q,k,v (B,H,d), i_g,f_g (B,H) pre-activations."""
    C, n, m = state
    q, k, v, ig, fg = inp
    log_f = -jax.nn.softplus(-fg)          # log sigmoid(f)
    m_new = jnp.maximum(log_f + m, ig)
    i_act = jnp.exp(ig - m_new)            # stabilized exp gate
    f_act = jnp.exp(log_f + m - m_new)
    C = f_act[..., None, None] * C + i_act[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n = f_act[..., None] * n + i_act[..., None] * k
    h_num = jnp.einsum("bhd,bhde->bhe", q, C)
    h_den = jnp.abs(jnp.einsum("bhd,bhd->bh", q, n))
    h = h_num / jnp.maximum(h_den, jnp.exp(-m_new))[..., None].clip(eps)
    return (C, n, m_new), h


def mlstm_block(x, p, cfg: ModelConfig, *, cache=None):
    """mLSTM block with up-projection (factor cfg.xlstm_proj_factor).

    x: (B,S,D). cache (decode): {"C": (B,H,d,d), "n": (B,H,d), "m": (B,H)}.
    """
    B, S, D = x.shape
    H = cfg.num_heads
    DI = int(cfg.xlstm_proj_factor * D)
    hd = DI // H
    up = jnp.einsum("bsd,de->bse", x, p["up_proj"])   # (B,S,2*DI)
    xin, z = jnp.split(up, 2, axis=-1)
    xin = shard_act(xin, "batch", "seq", "inner")

    def heads(w, b):
        return (jnp.einsum("bsi,ie->bse", xin, w) + b).reshape(B, S, H, -1)

    q = heads(p["wq"], p["bq"]).astype(jnp.float32)
    k = heads(p["wk"], p["bk"]).astype(jnp.float32) / jnp.sqrt(float(hd))
    v = heads(p["wv"], p["bv"]).astype(jnp.float32)
    ig = (jnp.einsum("bsi,ih->bsh", xin, p["wi_g"]) + p["bi_g"]).astype(jnp.float32)
    fg = (jnp.einsum("bsi,ih->bsh", xin, p["wf_g"]) + p["bf_g"]).astype(jnp.float32)

    if cache is not None:
        state0 = (cache["C"].astype(jnp.float32), cache["n"].astype(jnp.float32),
                  cache["m"].astype(jnp.float32))
    else:
        state0 = (jnp.zeros((B, H, hd, hd), jnp.float32),
                  jnp.zeros((B, H, hd), jnp.float32),
                  jnp.full((B, H), -1e30, jnp.float32))

    if S == 1:
        state, h = _mlstm_step(state0, (q[:, 0].reshape(B, H, hd),
                                        k[:, 0].reshape(B, H, hd),
                                        v[:, 0].reshape(B, H, hd), ig[:, 0], fg[:, 0]))
        h = h[:, None]
    else:
        xs = (q.swapaxes(0, 1).reshape(S, B, H, hd),
              k.swapaxes(0, 1).reshape(S, B, H, hd),
              v.swapaxes(0, 1).reshape(S, B, H, hd),
              ig.swapaxes(0, 1), fg.swapaxes(0, 1))
        state, hs = jax.lax.scan(lambda s, i: _mlstm_step(s, i), state0, xs)
        h = hs.swapaxes(0, 1)                                   # (B,S,H,hd)
    h = h.reshape(B, S, DI).astype(x.dtype)
    h = h * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", h, p["down_proj"])
    new_cache = {"C": state[0], "n": state[1], "m": state[2]}
    return shard_act(out, "batch", "seq", "embed_act"), new_cache


def slstm_block(x, p, cfg: ModelConfig, *, cache=None):
    """sLSTM block: scalar memory with per-head recurrent connections,
    followed by a gated FFN (factor 4/3, as in the paper)."""
    B, S, D = x.shape
    H = cfg.num_heads
    hd = D // H
    xh = x.reshape(B, S, H, hd)

    Rz, Ri, Rf, Ro = p["Rz"], p["Ri"], p["Rf"], p["Ro"]  # (H, hd, hd)

    def gate_x(w, b):
        return (jnp.einsum("bshd,hde->bshe", xh, w) + b).astype(jnp.float32)

    zx, ix_, fx, ox = (gate_x(p["Wz"], p["bz"]), gate_x(p["Wi"], p["bi"]),
                       gate_x(p["Wf"], p["bf"]), gate_x(p["Wo"], p["bo"]))

    if cache is not None:
        c0, n0, m0, h0 = (cache["c"].astype(jnp.float32), cache["n"].astype(jnp.float32),
                          cache["m"].astype(jnp.float32), cache["h"].astype(jnp.float32))
    else:
        c0 = jnp.zeros((B, H, hd), jnp.float32)
        n0 = jnp.ones((B, H, hd), jnp.float32)
        m0 = jnp.zeros((B, H, hd), jnp.float32)
        h0 = jnp.zeros((B, H, hd), jnp.float32)

    def step(state, inp):
        c, n, m, h = state
        zx_t, ix_t, fx_t, ox_t = inp

        def rec(R, hh):
            return jnp.einsum("bhd,hde->bhe", hh, R.astype(jnp.float32))

        zt = jnp.tanh(zx_t + rec(Rz, h))
        it = ix_t + rec(Ri, h)
        ft = fx_t + rec(Rf, h)
        ot = jax.nn.sigmoid(ox_t + rec(Ro, h))
        log_f = -jax.nn.softplus(-ft)
        m_new = jnp.maximum(log_f + m, it)
        i_act = jnp.exp(it - m_new)
        f_act = jnp.exp(log_f + m - m_new)
        c_new = f_act * c + i_act * zt
        n_new = f_act * n + i_act
        h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h_new), h_new

    if S == 1:
        state, h = step((c0, n0, m0, h0), (zx[:, 0], ix_[:, 0], fx[:, 0], ox[:, 0]))
        hs = h[:, None]
    else:
        xs = tuple(a.swapaxes(0, 1) for a in (zx, ix_, fx, ox))
        state, hs = jax.lax.scan(step, (c0, n0, m0, h0), xs)
        hs = hs.swapaxes(0, 1)
    y = hs.reshape(B, S, D).astype(x.dtype)
    # gated FFN
    g = jax.nn.gelu(jnp.einsum("bsd,df->bsf", y, p["ff_up"]))
    y = jnp.einsum("bsf,fd->bsd", g * jnp.einsum("bsd,df->bsf", y, p["ff_gate"]),
                   p["ff_down"])
    new_cache = {"c": state[0], "n": state[1], "m": state[2], "h": state[3]}
    return shard_act(y, "batch", "seq", "embed_act"), new_cache
