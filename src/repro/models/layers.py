"""Core transformer layers: norms, attention (GQA + sliding window + caches),
dense MLP, and capacity-based MoE. Pure-functional: params are dict trees
produced from ParamDecl declarations in transformer.py.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .rope import apply_rope
from .sharding import shard_act

NEG_INF = -1e30


@jax.custom_jvp
def opt_barrier(x):
    """optimization_barrier that differentiates as identity — the barrier
    only pins XLA scheduling on the primal; this JAX version has no
    differentiation rule for the primitive, which broke every train step."""
    return jax.lax.optimization_barrier(x)


@opt_barrier.defjvp
def _opt_barrier_jvp(primals, tangents):
    return opt_barrier(primals[0]), tangents[0]

# flip on for TPU deployments (or tests): route full-context attention
# through the Pallas flash kernel instead of the jnp path
USE_FLASH_KERNEL = False


def set_flash_kernel(enabled: bool) -> None:
    global USE_FLASH_KERNEL
    USE_FLASH_KERNEL = enabled


def quant_kv(x):
    """Symmetric per-(token, head) int8 quantization: (q8, scale)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(scale, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def _maybe_dequant(x, compute_dtype, scale=None):
    if x.dtype == jnp.int8:
        return (x.astype(jnp.float32) * scale).astype(compute_dtype)
    return x.astype(compute_dtype)


def rms_norm(x, w, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def _softcap(logits, cap: float):
    if cap and cap > 0:
        return jnp.tanh(logits / cap) * cap
    return logits


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def qkv_proj(x, p, cfg: ModelConfig):
    """x (B,S,D) -> q (B,S,H,hd), k/v (B,S,KV,hd)."""
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def causal_mask(q_start, q_len: int, kv_len: int, window: int = 0):
    """mask (q_len, kv_len): query i (global pos q_start+i) may attend kv j."""
    qpos = q_start + jnp.arange(q_len)[:, None]
    kpos = jnp.arange(kv_len)[None, :]
    m = kpos <= qpos
    if window > 0:
        m = m & (kpos > qpos - window)
    return m


def mha(q, k, v, mask, *, softcap: float = 0.0):
    """q (B,Tq,H,hd), k/v (B,Tk,KV,hd), mask broadcastable to (B,H,Tq,Tk).

    GQA is computed grouped (no materialized kv-head repeat): K/V stay at
    their stored width, so any cross-device gather of K/V moves KV heads,
    not H (see EXPERIMENTS §Perf, qwen2-vl iteration)."""
    B, Tq, H, hd = q.shape
    KV = k.shape[2]
    if KV == H:
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
        logits = _softcap(logits / math.sqrt(hd), softcap)
        logits = jnp.where(mask, logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", w, v)
    G = H // KV
    qg = q.reshape(B, Tq, KV, G, hd)
    logits = jnp.einsum("bqcgd,bkcd->bcgqk", qg, k).astype(jnp.float32)
    logits = _softcap(logits / math.sqrt(hd), softcap)
    logits = jnp.where(mask, logits, NEG_INF)  # (..,Tq,Tk) broadcasts
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bcgqk,bkcd->bqcgd", w, v)
    return out.reshape(B, Tq, H, hd)


def attention_block(x, p, cfg: ModelConfig, *, positions, q_start=0,
                    window: int = 0, cache=None, kv_override=None,
                    is_causal: bool = True):
    """Full attention sub-block (norm handled by caller).

    cache: None (train / full prefill) or dict {k,v: (B,Smax,KV,hd), idx}
    for incremental prefill/decode. Returns (out, new_cache).
    """
    B, S, D = x.shape
    q, k, v = qkv_proj(x, p, cfg)
    if kv_override is None:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_style)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_style)
    q = shard_act(q, "batch", "seq", "heads", None)
    new_cache = None
    if cache is not None:
        # write current k/v at [idx, idx+S), attend over the whole buffer.
        # int8 caches (beyond-paper serving optimization, EXPERIMENTS §Perf)
        # use symmetric per-(token, head) quantization with stored scales.
        idx = cache["idx"]
        new_cache = {"idx": idx + S}
        ks = vs = None
        if cache["k"].dtype == jnp.int8:
            k_st, k_sc = quant_kv(k)
            v_st, v_sc = quant_kv(v)
            new_cache["k_scale"] = jax.lax.dynamic_update_slice(
                cache["k_scale"], k_sc, (0, idx, 0, 0))
            new_cache["v_scale"] = jax.lax.dynamic_update_slice(
                cache["v_scale"], v_sc, (0, idx, 0, 0))
            ks, vs = new_cache["k_scale"], new_cache["v_scale"]
        else:
            k_st = k.astype(cache["k"].dtype)
            v_st = v.astype(cache["v"].dtype)
        ck = jax.lax.dynamic_update_slice(cache["k"], k_st, (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v_st, (0, idx, 0, 0))
        new_cache["k"], new_cache["v"] = ck, cv
        Tk = ck.shape[1]
        mask = causal_mask(idx, S, Tk, window)
        # entries beyond idx+S are unwritten -> masked off by causality
        out = mha(q, _maybe_dequant(ck, q.dtype, ks),
                  _maybe_dequant(cv, q.dtype, vs),
                  mask[None, None], softcap=cfg.logit_softcap)
    elif kv_override is not None:
        ck, cv = kv_override  # cross attention (whisper decoder)
        Tk = ck.shape[1]
        mask = jnp.ones((S, Tk), dtype=bool)
        out = mha(q, ck.astype(q.dtype), cv.astype(q.dtype), mask[None, None],
                  softcap=cfg.logit_softcap)
    else:
        if USE_FLASH_KERNEL and is_causal:
            # Pallas chunked-prefill flash kernel (interpret-mode on CPU,
            # native on TPU); oracle-equivalence in tests/test_optimizations
            from repro.kernels import ops as kops
            out = kops.prefill_attention(q, k, v, q_start=q_start,
                                         window=window,
                                         softcap=cfg.logit_softcap)
        else:
            if is_causal:
                mask = causal_mask(q_start, S, S, window)
            else:
                mask = jnp.ones((S, S), dtype=bool)
            out = mha(q, k, v, mask[None, None], softcap=cfg.logit_softcap)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    # barrier pins the TP all-reduce to bf16 here; without it XLA hoists the
    # reduce past the f32 norm upcast and moves 2x the bytes (§Perf iter 3)
    out = opt_barrier(out)
    return shard_act(out, "batch", "seq", "embed_act"), new_cache


def paged_attention_block(x, p, cfg: ModelConfig, *, positions, store, ctx,
                          impl: str = "gather"):
    """Attention sub-block over the batched paged KV cache (norm handled by
    caller, like ``attention_block``).

    x: (B, S, D) — S new tokens per sequence, right-padded (ragged geometry
    in ``ctx``); store: the *whole* flat ``PagedStackStore`` riding the
    transformer scan as carry (leaves (layers*pages_per_layer, page, KV,
    hd)); ctx: dict with
      block_table (B, max_pages) int32 — allocator page ids per sequence
        (padding entries point at the per-layer trash page id,
        ``store.trash_page``);
      lengths (B,) int32 — context tokens already written per sequence;
      new_lens (B,) int32 — valid new tokens per row (<= S);
      layer — this scan step's layer index (traced), offsetting every
        page access into the flat pool via ``store.layer_table``.
    impl: 'kernel' routes S==1 decode through the Pallas paged-attention
    kernel and S>1 chunked prefill through the paged-prefill flash kernel
    (native on TPU, interpret elsewhere) — both attend directly over
    block-table-indexed pages, no contiguous-context materialization;
    'gather' is the pure-JAX path — gather the table-width context and
    run the same ``mha`` the dense slot cache uses. Either way the
    attention geometry is the block table's width, which the executor
    length-buckets to the batch's live context (DESIGN.md §Ragged paged
    execution), so traffic scales with live context rather than the cap.

    Returns (out (B, S, D), new_store).
    """
    B, S, D = x.shape
    q, k, v = qkv_proj(x, p, cfg)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_style)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_style)
    q = shard_act(q, "batch", "seq", "heads", None)
    bt, lengths, new_lens = ctx["block_table"], ctx["lengths"], ctx["new_lens"]
    layer = ctx["layer"]
    store = store.write_batch(k, v, bt, lengths, new_lens, layer=layer)
    if impl == "kernel" and S == 1:
        from repro.kernels import ops as kops
        out = kops.paged_attention(
            q[:, 0], store.k_pages, store.v_pages,
            store.layer_table(bt, layer), lengths + new_lens,
            softcap=cfg.logit_softcap)[:, None]
    elif impl == "kernel":
        from repro.kernels import ops as kops
        out = kops.paged_prefill_attention(
            q, store.k_pages, store.v_pages, store.layer_table(bt, layer),
            lengths, new_lens, softcap=cfg.logit_softcap)
    else:
        # (B, max_pages*page, KV, hd) — this layer's resident pages only
        ck, cv = store.gather_batch(bt, layer=layer)
        Tk = ck.shape[1]
        qpos = lengths[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
        mask = jnp.arange(Tk, dtype=jnp.int32)[None, None, :] <= \
            qpos[:, :, None]                 # (B, S, Tk) per-row causal
        # mha branches on GQA: logits are (b,h,q,k) or (b,kv,g,q,k)
        mask = mask[:, None] if k.shape[2] == q.shape[2] \
            else mask[:, None, None]
        out = mha(q, ck.astype(q.dtype), cv.astype(q.dtype), mask,
                  softcap=cfg.logit_softcap)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    out = opt_barrier(out)
    return shard_act(out, "batch", "seq", "embed_act"), store


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_block(x, p):
    """SwiGLU MLP."""
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wi"])) * jnp.einsum(
        "bsd,df->bsf", x, p["wg"])
    h = shard_act(h, "batch", "seq", "mlp")
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


def moe_block(x, p, cfg: ModelConfig, *, group_size: int = 512):
    """Capacity-based top-k MoE with group-chunked einsum dispatch.

    Dispatch/combine are one-hot einsums (Switch-style, MXU-friendly); the
    sequence is chunked into groups so dispatch cost stays linear in S.
    Returns (out, aux_loss).
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    G = min(group_size, S)
    # pad S to a multiple of G
    pad = (-S) % G
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    ng = x.shape[1] // G
    xg = x.reshape(B * ng, G, D)

    logits = jnp.einsum("gsd,de->gse", xg, p["router"].astype(xg.dtype))
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topw, topi = jax.lax.top_k(gates, K)                      # (g,G,K)
    topw = topw / jnp.clip(topw.sum(-1, keepdims=True), 1e-9)

    C = max(1, int(math.ceil(G * K / E * cfg.capacity_factor)))
    oh = jax.nn.one_hot(topi, E, dtype=jnp.float32)           # (g,G,K,E)
    ohf = oh.reshape(-1, G * K, E)
    pos = jnp.cumsum(ohf, axis=1) - ohf                        # position within expert
    pos_sel = jnp.einsum("gte,gte->gt", pos, ohf)
    keep = (pos_sel < C).astype(jnp.float32)
    disp = ohf * keep[..., None]                               # (g,G*K,E)
    pos_oh = jax.nn.one_hot(pos_sel, C, dtype=jnp.float32)     # (g,G*K,C)
    dispatch = jnp.einsum("gte,gtc->gtec", disp, pos_oh).reshape(-1, G, K, E, C).sum(2)
    wexp = (oh * topw[..., None]).sum(2)                       # (g,G,E)
    combine = dispatch * wexp[..., None]

    xe = jnp.einsum("gsd,gsec->gecd", xg.astype(jnp.float32), dispatch)
    # 'moe_group' maps to the data axis under the moe_data optimization
    # (EXPERIMENTS §Perf): keeps the dispatch tensor batch-sharded instead of
    # replicated, eliminating the per-layer all-gather.
    xe = shard_act(xe.astype(x.dtype), "moe_group", "experts", None, "embed_act")
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["wi"])) * jnp.einsum(
        "gecd,edf->gecf", xe, p["wg"])
    h = shard_act(h, "moe_group", "experts", None, "expert_mlp")
    eo = jnp.einsum("gecf,efd->gecd", h, p["wo"])
    # combine in compute dtype: halves the TP all-reduce volume vs f32
    # (EXPERIMENTS §Perf iter 2); gates stay f32 upstream for routing quality
    y = jnp.einsum("gecd,gsec->gsd", eo, combine.astype(eo.dtype))
    y = opt_barrier(y.astype(x.dtype))
    y = y.reshape(B, S + pad, D)[:, :S]

    # Switch aux load-balance loss
    me = oh[..., 0, :] if K == 1 else oh.mean(2)
    density = me.mean(1)                                       # (g,E)
    density_proxy = gates.mean(1)
    aux = (density * density_proxy).sum(-1).mean() * (E ** 2) / (E * 1.0)
    return y, aux.astype(jnp.float32)
