"""Fleet tier: replica lifecycle, graceful drain, elastic repartitioning
(ISSUE 9 tentpole).

``Router`` (serving/router.py) treats replicas as permanently-identical
crash-only boxes: routing modes are static and the only lifecycle event is
a kill. Production fleets also *drain* replicas (rolling restarts,
scale-down), watch replica *health*, and re-shape modality partitions as
the arrival mix shifts (ElasticMM, PAPERS.md). ``Fleet`` layers all of
that on the same stepped co-simulation:

  * **lifecycle** — every replica is HEALTHY / DEGRADED / DRAINING / DEAD
    / RESTARTING. Health is scored each co-sim step from heartbeat-style
    signals off the stepped clock (brownout-ladder level, backlog depth,
    clock lag behind the fleet frontier) with a consecutive-observation
    hysteresis window, so one bad step never flaps a replica. A replica
    DEGRADED for ``auto_drain_window`` consecutive ticks starts its own
    graceful drain through the operator-drain path (ISSUE 10).
  * **crash recovery** (ISSUE 10) — killed and drained replicas restart
    on a schedule (``FleetConfig.restarts``) or fault-plan injection
    (``restart_delays``): a fresh engine takes the slot, optionally
    warms its prefix trie from the healthiest peer over the page-chain
    protocol, and re-enters routing only after the warm-up gate. With
    ``EngineConfig.journal=True`` every kill/drain cross-checks the
    replica's lifecycle-journal replay against its live accounting
    bit-exactly, and crashed in-flight work is recovered from the
    journal's replayed stage map (serving/journal.py).
  * **graceful drain** — a scheduled drain stops admissions to the
    replica, lets RUNNING decodes finish in place, and *migrates*
    everything else off via the page-chain transfer protocol
    (serving/migration.py): prefilled KV moves, the target re-prefills
    only the residual. When the last decode completes the replica leaves
    the fleet cleanly (state DEAD, nothing lost, caches audit empty).
  * **elastic repartitioning** — routing mode ``"elastic"`` is
    truck-isolation with a *dynamic* heavy-group size: a sliding window
    of routed arrivals tracks the truck share of estimated prefill work,
    and when the desired heavy-group size disagrees with the current one
    persistently (hysteresis: N consecutive decisions + a dwell time) the
    partition moves one replica at a time. A replica leaving the heavy
    group has its queued trucks migrated to the remaining heavy replicas.

**Bit-exactness contract**: with no drains scheduled, no kills in the
fault plan, and an inherited routing mode, ``Fleet.run_stepped`` produces
the exact timeline of ``Router.run_stepped``. The fleet defers routing to
arrival time (so repartitions can steer traffic mid-run), but routes in
the same arrival order with the same ``_route`` state, and only ever
routes a request before the co-sim frontier reaches its arrival — each
engine still ingests each request at the same local clock, so per-replica
simulations are unchanged. The no-events identity is gated in
benchmarks/fleet_tolerance.py.
"""
from __future__ import annotations

import enum
from collections import deque
from dataclasses import dataclass, field

from repro.core.scheduler import make_policy

from .engine import Engine
from .journal import replay, verify_engine
from .migration import MigrationConfig, migrate, warm_import
from .request import Request, VehicleClass
from .router import Router


class ReplicaState(str, enum.Enum):
    HEALTHY = "healthy"
    DEGRADED = "degraded"    # signals bad for >= health_window steps:
    #                          elastic routing steers new work away
    DRAINING = "draining"    # no admissions; decodes finishing; queued
    #                          work migrating off
    DEAD = "dead"            # crashed (kill) or drained to completion
    RESTARTING = "restarting"  # fresh engine in the slot, warming up:
    #                            not routable until the rejoin gate opens


@dataclass
class FleetConfig:
    """Fleet-tier knobs. The all-defaults config schedules nothing — the
    bit-exact configuration."""
    # operator schedule: replica index -> sim time to begin draining
    drains: dict = field(default_factory=dict)
    migration: MigrationConfig = field(default_factory=MigrationConfig)
    # -- elastic repartitioning ("elastic" routing mode) ----------------
    elastic_window: int = 32       # routed arrivals in the sliding window
    elastic_min_heavy: int = 1     # heavy-group size bounds
    elastic_max_heavy: int | None = None   # default: n_replicas - 1
    elastic_persist: int = 8       # consecutive decisions before a move
    elastic_dwell_s: float = 5.0   # min sim seconds between moves
    # -- health scoring -------------------------------------------------
    degraded_ladder_level: int = 2   # brownout level >= this is a signal
    degraded_backlog: int = 64       # non-terminal assigned reqs >= this
    degraded_lag_s: float = 30.0     # clock behind fleet frontier >= this
    health_window: int = 3           # consecutive observations to flip
    # -- crash recovery (ISSUE 10) --------------------------------------
    # operator restart schedule: replica -> seconds after its death that
    # a fresh engine restarts in the slot (FaultPlan.restart_delays is
    # the injected equivalent; this map takes precedence). Empty = no
    # replica ever comes back, the pre-ISSUE-10 behaviour.
    restarts: dict = field(default_factory=dict)
    restart_warmup_s: float = 5.0    # min RESTARTING dwell before rejoin
    restart_warm_pages: int = 0      # prefix-trie pages to import from
    #                                  the healthiest peer while warming
    #                                  (0 = rejoin cold)
    # auto-drain: a replica DEGRADED for this many consecutive health
    # ticks starts a graceful drain on its own (None = operator-only)
    auto_drain_window: int | None = None


@dataclass
class Fleet(Router):
    """A ``Router`` with replica lifecycle, drain, and elastic routing.
    All Router fields and routing modes apply; add ``routing="elastic"``
    and a ``FleetConfig`` to enable the fleet-only behaviors."""
    fleet: FleetConfig = field(default_factory=FleetConfig)

    def __post_init__(self):
        super().__post_init__()
        n = len(self.engines)
        self.replica_state = [ReplicaState.HEALTHY] * n
        # elastic partition: heavy group starts as the truck-isolation
        # suffix so "elastic" with a static mix behaves like the baseline
        self._heavy = set(range(n - self.truck_replicas, n))
        self._work_window: deque = deque(maxlen=self.fleet.elastic_window)
        self._persist = 0
        self._last_repartition = float("-inf")
        # drain bookkeeping
        self._drain_started: dict[int, float] = {}
        # health hysteresis: consecutive bad / good observations
        self._health_bad = [0] * n
        self._health_good = [0] * n
        # counters (surfaced via metrics.summarize_fleet)
        self.migrations_out = [0] * n
        self.migrations_in = [0] * n
        self.migrations_attempted = 0
        self.migrations_succeeded = 0
        self.migration_fallbacks = 0
        self.migration_noops = 0     # nothing prefilled: plain redispatch
        self.migration_retries = 0
        self.migrated_pages = 0
        self.deduped_pages = 0
        self.drain_events: list[dict] = []
        self.repartition_events: list[dict] = []
        self.health_events: list[dict] = []
        # crash recovery (ISSUE 10)
        self._death_time: list[float | None] = [None] * n
        self._restart_at: list[float | None] = [None] * n
        self._rejoin_at: list[float | None] = [None] * n
        self._drain_cause: dict[int, str] = {}
        self._degraded_streak = [0] * n
        self.restart_events: list[dict] = []
        self.retired: list[tuple[int, object]] = []  # (replica, old engine)
        # in-flight work from the LAST live replica's crash while a
        # restart is armed: orphaned (held for the restarted slot), not
        # lost — the whole-fleet outage is transient
        self._orphans: list[Request] = []
        # journal-replay cross-checks (serving/journal.py): every kill /
        # drain completion verifies replayed accounting against the live
        # engine bit-exactly; mismatches are real bugs, surfaced here
        self.journal_checks = 0
        self.journal_mismatches: list[str] = []

    # -- eligibility ----------------------------------------------------
    def _eligible(self) -> list[int]:
        """Replicas that may receive new or re-dispatched work."""
        return [j for j in range(len(self.engines))
                if self.alive[j]
                and self.replica_state[j] not in (ReplicaState.DRAINING,
                                                  ReplicaState.RESTARTING)]

    def _redispatch_pool(self) -> list[int]:
        pool = self._eligible()
        if pool:
            return pool
        # last resort: a draining replica beats losing the request
        return [j for j in range(len(self.engines)) if self.alive[j]]

    # -- routing --------------------------------------------------------
    def _route(self, req: Request) -> int:
        if self.routing != "elastic":
            i = super()._route(req)
            if self.alive[i] and self.replica_state[i] not in (
                    ReplicaState.DRAINING, ReplicaState.RESTARTING):
                return i
            # inherited mode picked an ineligible replica (only possible
            # once fleet events have fired, so bit-exactness is intact):
            # fall through to the best eligible one. The inherited mode
            # already bumped ``_load[i]`` (round-robin never bumps) —
            # remove that bump or load silently drifts upward on dead /
            # draining replicas across a long run, skewing every later
            # least-loaded comparison against them after a restart
            est = 0.0
            if self.routing != "round-robin":
                _vc, est, _kv = self.classifier.classify(
                    req.modality.value, req.text_tokens, req.mm_units)
                self._load[i] -= est
            j = min(self._redispatch_pool(),
                    key=lambda k: self._load[k])
            self._load[j] += est if est > 0.0 else req.est_prefill
            return j
        vclass, est_prefill, _ = self.classifier.classify(
            req.modality.value, req.text_tokens, req.mm_units)
        self._note_arrival(vclass, est_prefill)
        pool = self._redispatch_pool()
        healthy = [j for j in pool
                   if self.replica_state[j] is ReplicaState.HEALTHY]
        pool = healthy or pool
        heavy = [j for j in pool if j in self._heavy]
        light = [j for j in pool if j not in self._heavy]
        if vclass is VehicleClass.TRUCK:
            cand = heavy or pool
        elif vclass is VehicleClass.CAR:
            cand = (light + heavy) or pool
        else:
            cand = light or pool
        i = min(cand, key=lambda j: self._load[j])
        self._load[i] += est_prefill
        return i

    def _note_arrival(self, vclass, est_prefill: float) -> None:
        self._work_window.append(
            (est_prefill, vclass is VehicleClass.TRUCK))

    def _desired_heavy(self) -> int | None:
        if len(self._work_window) < self._work_window.maxlen:
            return None              # window not yet representative
        total = sum(w for w, _t in self._work_window)
        if total <= 0:
            return None
        frac = sum(w for w, t in self._work_window if t) / total
        n = len(self._eligible())
        lo = self.fleet.elastic_min_heavy
        hi = self.fleet.elastic_max_heavy
        if hi is None:
            hi = max(lo, n - 1)
        return max(lo, min(hi, round(frac * n)))

    def _maybe_repartition(self, remaining, clk: float) -> None:
        """One hysteresis-gated partition move: grow or shrink the heavy
        group by a single replica, migrating queued trucks off a replica
        that leaves it."""
        cur = len([j for j in self._eligible() if j in self._heavy])
        want = self._desired_heavy()
        if want is None or want == cur:
            self._persist = 0
            return
        self._persist += 1
        if self._persist < self.fleet.elastic_persist or \
                clk - self._last_repartition < self.fleet.elastic_dwell_s:
            return
        self._persist = 0
        self._last_repartition = clk
        eligible = self._eligible()
        if want > cur:
            # promote the least-loaded light replica
            light = [j for j in eligible if j not in self._heavy]
            if not light:
                return
            j = min(light, key=lambda k: self._load[k])
            self._heavy.add(j)
            moved = 0
        else:
            # demote the least-loaded heavy replica and move its queued
            # trucks to the replicas staying heavy
            heavy = [j for j in eligible if j in self._heavy]
            if len(heavy) <= 1:
                return
            j = min(heavy, key=lambda k: self._load[k])
            self._heavy.discard(j)
            moved = self._migrate_queued_trucks(j, remaining)
        self.repartition_events.append({
            "time": clk, "replica": j,
            "direction": "grow" if want > cur else "shrink",
            "heavy": sorted(self._heavy & set(self._eligible())),
            "migrated": moved})

    def _migrate_queued_trucks(self, i: int, remaining) -> int:
        """Move queued (not yet decoding) trucks off replica ``i`` after
        it left the heavy group."""
        eng = self.engines[i]
        moved = 0
        for req in list(self._assigned[i]):
            if req.is_terminal or req.vclass is not VehicleClass.TRUCK:
                continue
            if req.state.value == "running":
                continue             # decodes finish in place
            self._move_request(i, req, remaining, eng.now)
            moved += 1
        return moved

    # -- migration ------------------------------------------------------
    def _move_request(self, i: int, req: Request, remaining,
                      start: float) -> None:
        """Migrate one non-terminal request off replica ``i`` via the
        page-chain protocol, falling back to plain re-dispatch (full
        re-prefill on the target) when the transfer degrades."""
        if req in remaining[i]:
            # routed but never ingested: nothing on replica i to move
            remaining[i].remove(req)
            self._assigned[i].remove(req)
            j = self._prefix_target(req)
            self._place(j, req, remaining)
            return
        self._assigned[i].remove(req)
        j = self._prefix_target(req)
        plan = self.faults
        self.migrations_attempted += 1
        res = migrate(
            self.engines[i], self.engines[j], req, start,
            self.fleet.migration, plan,
            src_kill=plan.kill_time(i) if plan else None,
            dst_kill=plan.kill_time(j) if plan else None)
        self.migration_retries += res.retries
        self.migrated_pages += res.pages_imported
        self.deduped_pages += res.pages_deduped
        if res.status == "aborted_target_dead":
            # nothing landed on j (it is about to crash): send the
            # request to the next-best replica instead, plain re-prefill
            self.migration_fallbacks += 1
            pool = [k for k in self._redispatch_pool() if k != j] or \
                self._redispatch_pool()
            j = max(pool, key=lambda k: (
                self.engines[k].allocator.match_prefix(
                    req.content_chunks(),
                    max(req.prompt_tokens - 1, 0)).tokens,
                -self._load[k]))
        elif res.status == "migrated":
            self.migrations_succeeded += 1
        elif res.status == "fallback" and res.chunks_sent == 0:
            # empty manifest — the request had no transferable pages yet
            # (still queued / barely prefilled): a plain re-dispatch, not
            # a protocol degradation
            self.migration_noops += 1
        else:
            self.migration_fallbacks += 1
        self.migrations_out[i] += 1
        self.migrations_in[j] += 1
        self._place(j, req, remaining)

    def _place(self, j: int, req: Request, remaining) -> None:
        self._load[j] += req.est_prefill
        remaining[j].append(req)
        remaining[j].sort(key=lambda r: r.arrival)
        self._assigned[j].append(req)

    # -- drains ---------------------------------------------------------
    def _start_drain(self, i: int, remaining, when: float,
                     cause: str = "operator") -> None:
        """One drain path for operator schedules and health-driven auto
        drains (ISSUE 10): only the ``cause`` tag differs."""
        self.replica_state[i] = ReplicaState.DRAINING
        self._drain_started[i] = when
        self._drain_cause[i] = cause
        eng = self.engines[i]
        moved = 0
        for req in list(self._assigned[i]):
            if req.is_terminal:
                continue
            if req.state.value == "running":
                continue             # decodes finish in place
            self._move_request(i, req, remaining, max(eng.now, when))
            moved += 1
        self.health_events.append(
            {"time": when, "replica": i, "state": "draining",
             "cause": cause})
        self._drain_moved = getattr(self, "_drain_moved", {})
        self._drain_moved[i] = moved

    def _finish_drain(self, i: int, remaining) -> None:
        eng = self.engines[i]
        self.alive[i] = False
        self.replica_state[i] = ReplicaState.DEAD
        start = self._drain_started[i]
        self.drain_events.append({
            "replica": i, "start": start, "end": eng.now,
            "duration": max(0.0, eng.now - start),
            "cause": self._drain_cause.get(i, "operator"),
            "migrated": getattr(self, "_drain_moved", {}).get(i, 0)})
        # a drained replica left cleanly: its journal replay must agree
        # with the (now empty) live accounting bit-exactly
        self._verify_journal(i, eng)
        self._death_time[i] = eng.now
        self._schedule_restart(i)

    def _tick_drains(self, pending, remaining, clk) -> None:
        # start loop: operator-scheduled drains only (auto drains start
        # from the health tick); each schedule entry fires at most once —
        # a replica that drained, restarted, and rejoined must not
        # re-drain off the same stale entry
        for i, t in self.fleet.drains.items():
            eng = self.engines[i]
            if not self.alive[i] or i in self._drain_started or \
                    self.replica_state[i] not in (ReplicaState.HEALTHY,
                                                  ReplicaState.DEGRADED):
                continue
            nxt = self._next_arrival(i, pending, remaining)
            if eng.now >= t or (clk is not None and clk >= t) or \
                    (eng.idle and (nxt is None or nxt > t)):
                self._start_drain(i, remaining, max(eng.now, t))
        # completion loop: every DRAINING replica, whatever started it.
        # Checked in the same tick a drain starts: a replica drained
        # while already idle leaves the fleet now, not on a later tick
        # that may never come
        for i, eng in enumerate(self.engines):
            if self.alive[i] and \
                    self.replica_state[i] is ReplicaState.DRAINING and \
                    eng.idle and not remaining[i] and all(
                        r.is_terminal for r in self._assigned[i]):
                self._finish_drain(i, remaining)

    # -- health ---------------------------------------------------------
    def _tick_health(self, remaining) -> None:
        cfg = self.fleet
        frontier = max((e.now for e, a in zip(self.engines, self.alive)
                        if a), default=0.0)
        for i, eng in enumerate(self.engines):
            st = self.replica_state[i]
            if st in (ReplicaState.DRAINING, ReplicaState.DEAD,
                      ReplicaState.RESTARTING):
                continue
            backlog = (len(remaining[i]) + len(eng.queues) +
                       len(eng.encode_queues) + len(eng.prefilling) +
                       len(eng.running))
            bad = (
                (eng.ladder is not None and
                 eng.ladder.level >= cfg.degraded_ladder_level)
                or backlog >= cfg.degraded_backlog
                or (backlog > 0 and
                    frontier - eng.now >= cfg.degraded_lag_s))
            if bad:
                self._health_bad[i] += 1
                self._health_good[i] = 0
            else:
                self._health_good[i] += 1
                self._health_bad[i] = 0
            if st is ReplicaState.HEALTHY and \
                    self._health_bad[i] >= cfg.health_window:
                self.replica_state[i] = ReplicaState.DEGRADED
                self.health_events.append(
                    {"time": eng.now, "replica": i, "state": "degraded"})
            elif st is ReplicaState.DEGRADED and \
                    self._health_good[i] >= cfg.health_window:
                self.replica_state[i] = ReplicaState.HEALTHY
                self.health_events.append(
                    {"time": eng.now, "replica": i, "state": "healthy"})
            # health-scored auto-drain (ISSUE 10): persistently DEGRADED
            # replicas initiate their own graceful drain through the
            # same path an operator schedule uses
            if self.replica_state[i] is ReplicaState.DEGRADED:
                self._degraded_streak[i] += 1
                if cfg.auto_drain_window is not None and \
                        self._degraded_streak[i] >= cfg.auto_drain_window:
                    self._degraded_streak[i] = 0
                    self._start_drain(i, remaining, eng.now, cause="auto")
            else:
                self._degraded_streak[i] = 0

    # -- journal cross-checks (ISSUE 10) ---------------------------------
    def _verify_journal(self, i: int, eng) -> None:
        """Replay the replica's journal and compare against its live
        accounting bit-exactly; record any divergence (a real bug in
        either derivation, never tolerated)."""
        if eng.journal is None:
            return
        self.journal_checks += 1
        for m in verify_engine(eng):
            self.journal_mismatches.append(f"replica {i}: {m}")

    def verify_journals(self) -> list[str]:
        """End-of-run sweep: replay-verify every engine that ever served
        — current slots and retired (pre-restart) engines alike. Returns
        the accumulated mismatch list (empty = every journal agrees with
        its live accounting bit-exactly)."""
        for i, eng in enumerate(self.engines):
            self._verify_journal(i, eng)
        for i, eng in self.retired:
            self._verify_journal(i, eng)
        return self.journal_mismatches

    # -- kill override ---------------------------------------------------
    def _kill(self, i: int, remaining) -> None:
        eng = self.engines[i]
        recovered_stages = None
        if eng.journal is not None:
            # crash recovery from the journal: the replayed in-flight set
            # (ingested here, not terminal, not exported) is exactly what
            # the dead replica still owed — cross-checked against the
            # live-state derivation the redispatch below acts on
            st = replay(eng.journal.records)
            jset = st.inflight
            rem_rids = {r.rid for r in remaining[i]}
            live = {r.rid for r in self._assigned[i]
                    if not r.is_terminal and r.rid not in rem_rids}
            if jset != live:
                self.journal_mismatches.append(
                    f"replica {i}: crash-recovery set: journal-only "
                    f"{sorted(jset - live)} live-only {sorted(live - jset)}")
            recovered_stages = {}
            for rid in jset:
                s = st.stage.get(rid, "?")
                recovered_stages[s] = recovered_stages.get(s, 0) + 1
        self.replica_state[i] = ReplicaState.DEAD
        pre_lost = len(self.lost)
        super()._kill(i, remaining)
        if recovered_stages is not None:
            # known stage at crash, straight from the journal (the kill
            # event's operator-facing recovery manifest)
            self.kill_events[-1]["recovered_stages"] = recovered_stages
        # post-export the dead engine must audit clean — journal replay
        # included (every recovered request shows release+export)
        self._verify_journal(i, eng)
        self._death_time[i] = eng.now
        self._schedule_restart(i)
        if len(self.lost) > pre_lost and self._restarts_armed():
            # the last live replica died with a restart armed somewhere:
            # its in-flight is orphaned, not lost — redispatched when a
            # slot rejoins (_tick_restarts)
            self._orphans.extend(self.lost[pre_lost:])
            del self.lost[pre_lost:]

    # -- restart & rejoin (ISSUE 10) --------------------------------------
    def _schedule_restart(self, i: int) -> None:
        """Arm a restart for a replica that just died (kill or drain):
        the fleet schedule takes precedence, then the fault plan's
        injected delay; neither = the slot stays down forever."""
        delay = self.fleet.restarts.get(i)
        if delay is None and self.faults is not None:
            delay = self.faults.restart_delay(i)
        if delay is not None:
            self._restart_at[i] = self._death_time[i] + delay

    def _warm_source(self, i: int) -> int | None:
        """Healthiest peer to warm replica ``i``'s prefix trie from:
        prefer HEALTHY over DEGRADED, then the largest cached trie."""
        cands = [j for j in range(len(self.engines))
                 if j != i and self.alive[j]
                 and self.replica_state[j] in (ReplicaState.HEALTHY,
                                               ReplicaState.DEGRADED)]
        if not cands:
            return None
        return max(cands, key=lambda j: (
            self.replica_state[j] is ReplicaState.HEALTHY,
            self.engines[j].allocator.cached_pages, -j))

    def _do_restart(self, i: int, at: float) -> None:
        """A fresh engine takes the dead replica's slot: cold allocator,
        cold caches, fresh journal, zeroed executor state — everything
        the old process held is gone (it was exported/verified at death).
        Optionally warms its prefix trie from the healthiest peer over
        the page-chain transfer protocol; re-enters routing only when
        the warm-up gate opens (``_rejoin_at``)."""
        old_ex = self.executors[i]
        ex = old_ex.fresh() if hasattr(old_ex, "fresh") else old_ex
        self.executors[i] = ex
        self.retired.append((i, self.engines[i]))
        eng = Engine(make_policy(self.policy), ex, self.classifier,
                     self.engine_cfg, faults=self.faults)
        eng.now = at
        self.engines[i] = eng
        self.alive[i] = True
        self.replica_state[i] = ReplicaState.RESTARTING
        self._restart_at[i] = None
        self._load[i] = 0.0
        self._health_bad[i] = self._health_good[i] = 0
        self._degraded_streak[i] = 0
        ready = at + self.fleet.restart_warmup_s
        src = None
        warm_imported = warm_deduped = 0
        if self.fleet.restart_warm_pages > 0:
            src = self._warm_source(i)
            if src is not None:
                res = warm_import(self.engines[src], eng, at,
                                  self.fleet.migration, self.faults,
                                  self.fleet.restart_warm_pages)
                warm_imported = res.pages_imported
                warm_deduped = res.pages_deduped
                self.migrated_pages += res.pages_imported
                self.deduped_pages += res.pages_deduped
                self.migration_retries += res.retries
                ready = max(ready, res.finish_time)
        self._rejoin_at[i] = ready
        self.restart_events.append({
            "replica": i, "died": self._death_time[i], "restarted": at,
            "rejoin_at": ready, "warm_source": src,
            "warm_pages_imported": warm_imported,
            "warm_pages_deduped": warm_deduped})

    def _tick_restarts(self, pending, remaining, clk) -> None:
        """Fire armed restarts the co-sim frontier has reached and open
        rejoin gates for warmed-up RESTARTING replicas. With no live
        clock (fleet idle or fully dead) a pending restart fires by
        jumping to its scheduled time — the co-sim analogue of the
        idle-jump, and what keeps a whole-fleet outage with a scheduled
        restart from losing the tail of the workload."""
        for i in range(len(self.engines)):
            at = self._restart_at[i]
            if at is not None and (clk is None or clk >= at):
                self._do_restart(i, at)
        for i in range(len(self.engines)):
            ra = self._rejoin_at[i]
            if ra is None or \
                    self.replica_state[i] is not ReplicaState.RESTARTING:
                continue
            eng = self.engines[i]
            ref = max(clk, eng.now) if clk is not None else eng.now
            if clk is None or ref >= ra:
                self._rejoin_at[i] = None
                self.replica_state[i] = ReplicaState.HEALTHY
                self.health_events.append(
                    {"time": max(ref, ra), "replica": i,
                     "state": "rejoined"})
        if self._orphans and self._eligible():
            # a slot rejoined after a whole-fleet outage: the crash's
            # orphaned in-flight (already reset for redispatch) lands on
            # the best eligible replica, prefix-aware like any failover
            orphans, self._orphans = self._orphans, []
            for req in orphans:
                j = self._prefix_target(req)
                self._load[j] += req.est_prefill
                remaining[j].append(req)
                self._assigned[j].append(req)
                self.redispatched += 1
            for lst in remaining:
                lst.sort(key=lambda r: r.arrival)

    def _restarts_armed(self) -> bool:
        return any(at is not None for at in self._restart_at) or \
            ReplicaState.RESTARTING in self.replica_state

    def _revivable(self) -> bool:
        return self._restarts_armed()

    # -- stepped co-sim hooks --------------------------------------------
    def _live_clock(self, remaining) -> float | None:
        live = [j for j in range(len(self.engines)) if self.alive[j]
                and (not self.engines[j].idle or remaining[j])]
        if not live:
            return None
        return min(self.engines[j].now for j in live)

    def _next_arrival(self, i, pending, remaining):
        nxt = super()._next_arrival(i, pending, remaining)
        if pending:
            p = pending[0].arrival
            nxt = p if nxt is None else min(nxt, p)
        return nxt

    def _dispatch_arrivals(self, reqs_sorted, remaining):
        # defer routing to arrival time: elastic repartitions (and
        # drains/health) must be able to steer traffic mid-run
        return list(reqs_sorted)

    def _fleet_tick(self, pending, remaining):
        clk = self._live_clock(remaining)
        if self._restarts_armed():
            self._tick_restarts(pending, remaining, clk)
            clk = self._live_clock(remaining)
        if self.fleet.drains or ReplicaState.DRAINING in self.replica_state:
            self._tick_drains(pending, remaining, clk)
            clk = self._live_clock(remaining)
        self._tick_health(remaining)
        # route every arrival the co-sim frontier has reached; the clock
        # is recomputed per route because routing to a lagging idle
        # replica can pull the frontier back
        while pending:
            clk = self._live_clock(remaining)
            if clk is None:
                break                # no live engine: force-route below
            if pending[0].arrival > clk:
                break
            self._admit(pending.pop(0), remaining, clk)
        if pending and self._live_clock(remaining) is None:
            if not any(self.alive):
                # whole fleet is down — but a scheduled restart means the
                # outage is transient: jump to it instead of losing the
                # tail of the workload
                if self._restarts_armed():
                    self._tick_restarts(pending, remaining, None)
                if not any(self.alive):
                    self.lost.extend(pending)   # fleet gone for good
                    return []
            # fleet fully idle: route the next arrival so the co-sim can
            # jump to it (mirrors the base router's idle-jump semantics)
            req = pending.pop(0)
            self._admit(req, remaining, req.arrival)
        return pending

    def _admit(self, req: Request, remaining, clk: float) -> None:
        i = self._route(req)
        remaining[i].append(req)
        self._assigned[i].append(req)
        if self.routing == "elastic":
            self._maybe_repartition(remaining, max(clk, req.arrival))

    def run_stepped(self, requests: list[Request],
                    max_steps: int = 2_000_000) -> list[Request]:
        done = super().run_stepped(requests, max_steps)
        # requests that finished on a retired engine (before its slot
        # restarted) are completions too — the current engines' lists
        # alone under-report them
        seen = {r.rid for r in done}
        done.extend(r for _i, eng in self.retired for r in eng.finished
                    if r.rid not in seen)
        return done
