"""Fleet tier: replica lifecycle, graceful drain, elastic repartitioning
(ISSUE 9 tentpole).

``Router`` (serving/router.py) treats replicas as permanently-identical
crash-only boxes: routing modes are static and the only lifecycle event is
a kill. Production fleets also *drain* replicas (rolling restarts,
scale-down), watch replica *health*, and re-shape modality partitions as
the arrival mix shifts (ElasticMM, PAPERS.md). ``Fleet`` layers all of
that on the same stepped co-simulation:

  * **lifecycle** — every replica is HEALTHY / DEGRADED / DRAINING / DEAD.
    Health is scored each co-sim step from heartbeat-style signals off the
    stepped clock (brownout-ladder level, backlog depth, clock lag behind
    the fleet frontier) with a consecutive-observation hysteresis window,
    so one bad step never flaps a replica.
  * **graceful drain** — a scheduled drain stops admissions to the
    replica, lets RUNNING decodes finish in place, and *migrates*
    everything else off via the page-chain transfer protocol
    (serving/migration.py): prefilled KV moves, the target re-prefills
    only the residual. When the last decode completes the replica leaves
    the fleet cleanly (state DEAD, nothing lost, caches audit empty).
  * **elastic repartitioning** — routing mode ``"elastic"`` is
    truck-isolation with a *dynamic* heavy-group size: a sliding window
    of routed arrivals tracks the truck share of estimated prefill work,
    and when the desired heavy-group size disagrees with the current one
    persistently (hysteresis: N consecutive decisions + a dwell time) the
    partition moves one replica at a time. A replica leaving the heavy
    group has its queued trucks migrated to the remaining heavy replicas.

**Bit-exactness contract**: with no drains scheduled, no kills in the
fault plan, and an inherited routing mode, ``Fleet.run_stepped`` produces
the exact timeline of ``Router.run_stepped``. The fleet defers routing to
arrival time (so repartitions can steer traffic mid-run), but routes in
the same arrival order with the same ``_route`` state, and only ever
routes a request before the co-sim frontier reaches its arrival — each
engine still ingests each request at the same local clock, so per-replica
simulations are unchanged. The no-events identity is gated in
benchmarks/fleet_tolerance.py.
"""
from __future__ import annotations

import enum
from collections import deque
from dataclasses import dataclass, field

from .migration import MigrationConfig, migrate
from .request import Request, VehicleClass
from .router import Router


class ReplicaState(str, enum.Enum):
    HEALTHY = "healthy"
    DEGRADED = "degraded"    # signals bad for >= health_window steps:
    #                          elastic routing steers new work away
    DRAINING = "draining"    # no admissions; decodes finishing; queued
    #                          work migrating off
    DEAD = "dead"            # crashed (kill) or drained to completion


@dataclass
class FleetConfig:
    """Fleet-tier knobs. The all-defaults config schedules nothing — the
    bit-exact configuration."""
    # operator schedule: replica index -> sim time to begin draining
    drains: dict = field(default_factory=dict)
    migration: MigrationConfig = field(default_factory=MigrationConfig)
    # -- elastic repartitioning ("elastic" routing mode) ----------------
    elastic_window: int = 32       # routed arrivals in the sliding window
    elastic_min_heavy: int = 1     # heavy-group size bounds
    elastic_max_heavy: int | None = None   # default: n_replicas - 1
    elastic_persist: int = 8       # consecutive decisions before a move
    elastic_dwell_s: float = 5.0   # min sim seconds between moves
    # -- health scoring -------------------------------------------------
    degraded_ladder_level: int = 2   # brownout level >= this is a signal
    degraded_backlog: int = 64       # non-terminal assigned reqs >= this
    degraded_lag_s: float = 30.0     # clock behind fleet frontier >= this
    health_window: int = 3           # consecutive observations to flip


@dataclass
class Fleet(Router):
    """A ``Router`` with replica lifecycle, drain, and elastic routing.
    All Router fields and routing modes apply; add ``routing="elastic"``
    and a ``FleetConfig`` to enable the fleet-only behaviors."""
    fleet: FleetConfig = field(default_factory=FleetConfig)

    def __post_init__(self):
        super().__post_init__()
        n = len(self.engines)
        self.replica_state = [ReplicaState.HEALTHY] * n
        # elastic partition: heavy group starts as the truck-isolation
        # suffix so "elastic" with a static mix behaves like the baseline
        self._heavy = set(range(n - self.truck_replicas, n))
        self._work_window: deque = deque(maxlen=self.fleet.elastic_window)
        self._persist = 0
        self._last_repartition = float("-inf")
        # drain bookkeeping
        self._drain_started: dict[int, float] = {}
        # health hysteresis: consecutive bad / good observations
        self._health_bad = [0] * n
        self._health_good = [0] * n
        # counters (surfaced via metrics.summarize_fleet)
        self.migrations_out = [0] * n
        self.migrations_in = [0] * n
        self.migrations_attempted = 0
        self.migrations_succeeded = 0
        self.migration_fallbacks = 0
        self.migration_noops = 0     # nothing prefilled: plain redispatch
        self.migration_retries = 0
        self.migrated_pages = 0
        self.deduped_pages = 0
        self.drain_events: list[dict] = []
        self.repartition_events: list[dict] = []
        self.health_events: list[dict] = []

    # -- eligibility ----------------------------------------------------
    def _eligible(self) -> list[int]:
        """Replicas that may receive new or re-dispatched work."""
        return [j for j in range(len(self.engines))
                if self.alive[j]
                and self.replica_state[j] is not ReplicaState.DRAINING]

    def _redispatch_pool(self) -> list[int]:
        pool = self._eligible()
        if pool:
            return pool
        # last resort: a draining replica beats losing the request
        return [j for j in range(len(self.engines)) if self.alive[j]]

    # -- routing --------------------------------------------------------
    def _route(self, req: Request) -> int:
        if self.routing != "elastic":
            i = super()._route(req)
            if self.alive[i] and \
                    self.replica_state[i] is not ReplicaState.DRAINING:
                return i
            # inherited mode picked an ineligible replica (only possible
            # once fleet events have fired, so bit-exactness is intact):
            # fall through to the best eligible one
            j = min(self._redispatch_pool(),
                    key=lambda k: self._load[k])
            self._load[j] += req.est_prefill
            return j
        vclass, est_prefill, _ = self.classifier.classify(
            req.modality.value, req.text_tokens, req.mm_units)
        self._note_arrival(vclass, est_prefill)
        pool = self._redispatch_pool()
        healthy = [j for j in pool
                   if self.replica_state[j] is ReplicaState.HEALTHY]
        pool = healthy or pool
        heavy = [j for j in pool if j in self._heavy]
        light = [j for j in pool if j not in self._heavy]
        if vclass is VehicleClass.TRUCK:
            cand = heavy or pool
        elif vclass is VehicleClass.CAR:
            cand = (light + heavy) or pool
        else:
            cand = light or pool
        i = min(cand, key=lambda j: self._load[j])
        self._load[i] += est_prefill
        return i

    def _note_arrival(self, vclass, est_prefill: float) -> None:
        self._work_window.append(
            (est_prefill, vclass is VehicleClass.TRUCK))

    def _desired_heavy(self) -> int | None:
        if len(self._work_window) < self._work_window.maxlen:
            return None              # window not yet representative
        total = sum(w for w, _t in self._work_window)
        if total <= 0:
            return None
        frac = sum(w for w, t in self._work_window if t) / total
        n = len(self._eligible())
        lo = self.fleet.elastic_min_heavy
        hi = self.fleet.elastic_max_heavy
        if hi is None:
            hi = max(lo, n - 1)
        return max(lo, min(hi, round(frac * n)))

    def _maybe_repartition(self, remaining, clk: float) -> None:
        """One hysteresis-gated partition move: grow or shrink the heavy
        group by a single replica, migrating queued trucks off a replica
        that leaves it."""
        cur = len([j for j in self._eligible() if j in self._heavy])
        want = self._desired_heavy()
        if want is None or want == cur:
            self._persist = 0
            return
        self._persist += 1
        if self._persist < self.fleet.elastic_persist or \
                clk - self._last_repartition < self.fleet.elastic_dwell_s:
            return
        self._persist = 0
        self._last_repartition = clk
        eligible = self._eligible()
        if want > cur:
            # promote the least-loaded light replica
            light = [j for j in eligible if j not in self._heavy]
            if not light:
                return
            j = min(light, key=lambda k: self._load[k])
            self._heavy.add(j)
            moved = 0
        else:
            # demote the least-loaded heavy replica and move its queued
            # trucks to the replicas staying heavy
            heavy = [j for j in eligible if j in self._heavy]
            if len(heavy) <= 1:
                return
            j = min(heavy, key=lambda k: self._load[k])
            self._heavy.discard(j)
            moved = self._migrate_queued_trucks(j, remaining)
        self.repartition_events.append({
            "time": clk, "replica": j,
            "direction": "grow" if want > cur else "shrink",
            "heavy": sorted(self._heavy & set(self._eligible())),
            "migrated": moved})

    def _migrate_queued_trucks(self, i: int, remaining) -> int:
        """Move queued (not yet decoding) trucks off replica ``i`` after
        it left the heavy group."""
        eng = self.engines[i]
        moved = 0
        for req in list(self._assigned[i]):
            if req.is_terminal or req.vclass is not VehicleClass.TRUCK:
                continue
            if req.state.value == "running":
                continue             # decodes finish in place
            self._move_request(i, req, remaining, eng.now)
            moved += 1
        return moved

    # -- migration ------------------------------------------------------
    def _move_request(self, i: int, req: Request, remaining,
                      start: float) -> None:
        """Migrate one non-terminal request off replica ``i`` via the
        page-chain protocol, falling back to plain re-dispatch (full
        re-prefill on the target) when the transfer degrades."""
        if req in remaining[i]:
            # routed but never ingested: nothing on replica i to move
            remaining[i].remove(req)
            self._assigned[i].remove(req)
            j = self._prefix_target(req)
            self._place(j, req, remaining)
            return
        self._assigned[i].remove(req)
        j = self._prefix_target(req)
        plan = self.faults
        self.migrations_attempted += 1
        res = migrate(
            self.engines[i], self.engines[j], req, start,
            self.fleet.migration, plan,
            src_kill=plan.kill_time(i) if plan else None,
            dst_kill=plan.kill_time(j) if plan else None)
        self.migration_retries += res.retries
        self.migrated_pages += res.pages_imported
        self.deduped_pages += res.pages_deduped
        if res.status == "aborted_target_dead":
            # nothing landed on j (it is about to crash): send the
            # request to the next-best replica instead, plain re-prefill
            self.migration_fallbacks += 1
            pool = [k for k in self._redispatch_pool() if k != j] or \
                self._redispatch_pool()
            j = max(pool, key=lambda k: (
                self.engines[k].allocator.match_prefix(
                    req.content_chunks(),
                    max(req.prompt_tokens - 1, 0)).tokens,
                -self._load[k]))
        elif res.status == "migrated":
            self.migrations_succeeded += 1
        elif res.status == "fallback" and res.chunks_sent == 0:
            # empty manifest — the request had no transferable pages yet
            # (still queued / barely prefilled): a plain re-dispatch, not
            # a protocol degradation
            self.migration_noops += 1
        else:
            self.migration_fallbacks += 1
        self.migrations_out[i] += 1
        self.migrations_in[j] += 1
        self._place(j, req, remaining)

    def _place(self, j: int, req: Request, remaining) -> None:
        self._load[j] += req.est_prefill
        remaining[j].append(req)
        remaining[j].sort(key=lambda r: r.arrival)
        self._assigned[j].append(req)

    # -- drains ---------------------------------------------------------
    def _start_drain(self, i: int, remaining, when: float) -> None:
        self.replica_state[i] = ReplicaState.DRAINING
        self._drain_started[i] = when
        eng = self.engines[i]
        moved = 0
        for req in list(self._assigned[i]):
            if req.is_terminal:
                continue
            if req.state.value == "running":
                continue             # decodes finish in place
            self._move_request(i, req, remaining, max(eng.now, when))
            moved += 1
        self.health_events.append(
            {"time": when, "replica": i, "state": "draining"})
        self._drain_moved = getattr(self, "_drain_moved", {})
        self._drain_moved[i] = moved

    def _finish_drain(self, i: int, remaining) -> None:
        eng = self.engines[i]
        self.alive[i] = False
        self.replica_state[i] = ReplicaState.DEAD
        start = self._drain_started[i]
        self.drain_events.append({
            "replica": i, "start": start, "end": eng.now,
            "duration": max(0.0, eng.now - start),
            "migrated": getattr(self, "_drain_moved", {}).get(i, 0)})

    def _tick_drains(self, pending, remaining, clk) -> None:
        for i, t in self.fleet.drains.items():
            eng = self.engines[i]
            if not self.alive[i]:
                continue
            if self.replica_state[i] is not ReplicaState.DRAINING:
                nxt = self._next_arrival(i, pending, remaining)
                if eng.now >= t or (clk is not None and clk >= t) or \
                        (eng.idle and (nxt is None or nxt > t)):
                    self._start_drain(i, remaining, max(eng.now, t))
            # completion is checked in the same tick a drain starts: a
            # replica drained while already idle leaves the fleet now,
            # not on a later tick that may never come
            if self.replica_state[i] is ReplicaState.DRAINING and \
                    eng.idle and not remaining[i] and all(
                        r.is_terminal for r in self._assigned[i]):
                self._finish_drain(i, remaining)

    # -- health ---------------------------------------------------------
    def _tick_health(self, remaining) -> None:
        cfg = self.fleet
        frontier = max((e.now for e, a in zip(self.engines, self.alive)
                        if a), default=0.0)
        for i, eng in enumerate(self.engines):
            st = self.replica_state[i]
            if st in (ReplicaState.DRAINING, ReplicaState.DEAD):
                continue
            backlog = (len(remaining[i]) + len(eng.queues) +
                       len(eng.encode_queues) + len(eng.prefilling) +
                       len(eng.running))
            bad = (
                (eng.ladder is not None and
                 eng.ladder.level >= cfg.degraded_ladder_level)
                or backlog >= cfg.degraded_backlog
                or (backlog > 0 and
                    frontier - eng.now >= cfg.degraded_lag_s))
            if bad:
                self._health_bad[i] += 1
                self._health_good[i] = 0
            else:
                self._health_good[i] += 1
                self._health_bad[i] = 0
            if st is ReplicaState.HEALTHY and \
                    self._health_bad[i] >= cfg.health_window:
                self.replica_state[i] = ReplicaState.DEGRADED
                self.health_events.append(
                    {"time": eng.now, "replica": i, "state": "degraded"})
            elif st is ReplicaState.DEGRADED and \
                    self._health_good[i] >= cfg.health_window:
                self.replica_state[i] = ReplicaState.HEALTHY
                self.health_events.append(
                    {"time": eng.now, "replica": i, "state": "healthy"})

    # -- kill override ---------------------------------------------------
    def _kill(self, i: int, remaining) -> None:
        self.replica_state[i] = ReplicaState.DEAD
        super()._kill(i, remaining)

    # -- stepped co-sim hooks --------------------------------------------
    def _live_clock(self, remaining) -> float | None:
        live = [j for j in range(len(self.engines)) if self.alive[j]
                and (not self.engines[j].idle or remaining[j])]
        if not live:
            return None
        return min(self.engines[j].now for j in live)

    def _next_arrival(self, i, pending, remaining):
        nxt = super()._next_arrival(i, pending, remaining)
        if pending:
            p = pending[0].arrival
            nxt = p if nxt is None else min(nxt, p)
        return nxt

    def _dispatch_arrivals(self, reqs_sorted, remaining):
        # defer routing to arrival time: elastic repartitions (and
        # drains/health) must be able to steer traffic mid-run
        return list(reqs_sorted)

    def _fleet_tick(self, pending, remaining):
        clk = self._live_clock(remaining)
        if self.fleet.drains:
            self._tick_drains(pending, remaining, clk)
            clk = self._live_clock(remaining)
        self._tick_health(remaining)
        # route every arrival the co-sim frontier has reached; the clock
        # is recomputed per route because routing to a lagging idle
        # replica can pull the frontier back
        while pending:
            clk = self._live_clock(remaining)
            if clk is None:
                break                # no live engine: force-route below
            if pending[0].arrival > clk:
                break
            self._admit(pending.pop(0), remaining, clk)
        if pending and self._live_clock(remaining) is None:
            if not any(self.alive):
                self.lost.extend(pending)   # whole fleet is gone
                return []
            # fleet fully idle: route the next arrival so the co-sim can
            # jump to it (mirrors the base router's idle-jump semantics)
            req = pending.pop(0)
            self._admit(req, remaining, req.arrival)
        return pending

    def _admit(self, req: Request, remaining, clk: float) -> None:
        i = self._route(req)
        remaining[i].append(req)
        self._assigned[i].append(req)
        if self.routing == "elastic":
            self._maybe_repartition(remaining, max(clk, req.arrival))
