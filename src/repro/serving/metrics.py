"""Aggregate serving metrics (the paper's reported quantities)."""
from __future__ import annotations

import numpy as np

from .request import Request, State

GROUPS = ("motorcycle", "car", "truck", "overall")


def lifecycle_counts(reqs: list[Request]) -> dict:
    """How every request ended (ISSUE 6): the chaos benchmark asserts
    these partition the workload — each request reaches exactly one
    terminal state, none is lost in flight, none finishes twice."""
    by_state: dict[str, int] = {}
    for r in reqs:
        by_state[r.state.value] = by_state.get(r.state.value, 0) + 1
    return {
        "finished": by_state.get(State.FINISHED.value, 0),
        "rejected": by_state.get(State.REJECTED.value, 0),
        "failed": by_state.get(State.FAILED.value, 0),
        "cancelled": by_state.get(State.CANCELLED.value, 0),
        "in_flight": sum(n for s, n in by_state.items()
                         if s not in ("finished", "rejected", "failed",
                                      "cancelled")),
        "shed": sum(1 for r in reqs
                    if r.error is not None and r.error.startswith(
                        "load shed")),
        "redispatched": sum(1 for r in reqs if r.redispatches > 0),
    }


def _group(reqs: list[Request], g: str) -> list[Request]:
    if g == "overall":
        return reqs
    return [r for r in reqs if r.vclass is not None and r.vclass.value == g]


def summarize(reqs: list[Request]) -> dict:
    """Per-class + overall: TTFT, normalized latency, SLO violation rate &
    severity, preemption counts/time (paper Figs. 3/8/10/11...).

    Latency statistics (TTFT / norm latency / SLO violation) are computed
    over COMPLETED requests only (ISSUE 8): a rejected or failed request
    has no meaningful latency, and folding its partial timestamps into
    percentiles skews them.  Non-completed outcomes are reported as
    separate counts instead, so overload runs stay honest — a policy
    cannot 'improve' its p90 by rejecting its slowest class."""
    out = {}
    for g in GROUPS:
        rs = _group(reqs, g)
        if not rs:
            out[g] = None
            continue
        done = [r for r in rs if r.state is State.FINISHED]
        ttft = np.array([r.ttft() for r in done if r.ttft() is not None])
        norm = np.array([r.norm_latency() for r in done
                         if r.norm_latency() is not None])
        viol = np.array([r.slo_violated() for r in done])
        sev = np.array([r.violation_severity() for r in done
                        if r.slo_violated()])
        mm = [r for r in done if r.mm_units > 0]
        enc_waits = [bd["encode_wait"] for r in mm
                     if (bd := r.ttft_breakdown()) is not None]
        out[g] = {
            "n": len(rs),
            "finished": len(done),
            "rejected": sum(r.state is State.REJECTED for r in rs),
            "failed": sum(r.state is State.FAILED for r in rs),
            "cancelled": sum(r.state is State.CANCELLED for r in rs),
            "shed": sum(1 for r in rs if r.error is not None
                        and r.error.startswith("load shed")),
            "ttft_avg": float(ttft.mean()) if len(ttft) else float("nan"),
            "ttft_p90": float(np.percentile(ttft, 90)) if len(ttft) else float("nan"),
            "ttft_p99": float(np.percentile(ttft, 99)) if len(ttft) else float("nan"),
            "norm_latency_avg": float(norm.mean()) if len(norm) else float("nan"),
            "slo_violation_rate": float(viol.mean()) if len(viol) else 0.0,
            "violation_severity_avg": float(sev.mean()) if len(sev) else 0.0,
            "preemptions": int(sum(r.preemptions for r in rs)),
            "preempted_time": float(sum(r.preempted_time for r in rs)),
            # decoupled encode stage (mm requests only)
            "encode_wait_avg": (float(np.mean(enc_waits)) if enc_waits
                                else 0.0),
            "encode_cache_hit_rate": (sum(r.encode_cache_hit for r in mm)
                                      / len(mm) if mm else 0.0),
            # KV prefix cache: prompt tokens served from cached pages
            "cached_prefix_tokens": int(sum(r.cached_prefix_tokens
                                            for r in rs)),
            "prefix_hit_rate": (sum(r.cached_prefix_tokens > 0 for r in rs)
                                / len(rs)),
        }
    return out


def summarize_tenants(reqs: list[Request],
                      duration: float | None = None) -> dict:
    """Per-tenant goodput / rejection / attainment counters (ISSUE 8).

    The fairness check the benchmark gates on: under overload no tenant
    may be fully starved (zero served) at a vehicle class where another
    tenant is being served — modality-aware rejection must discriminate
    by *class*, never by client identity."""
    tenants = sorted({r.tenant for r in reqs})
    if duration is None and reqs:
        t0 = min(r.arrival for r in reqs)
        t1 = max((r.finish_time for r in reqs
                  if r.finish_time is not None), default=t0)
        duration = max(t1 - t0, 1e-9)
    out = {}
    for t in tenants:
        rs = [r for r in reqs if r.tenant == t]
        done = [r for r in rs if r.state is State.FINISHED]
        ok = [r for r in done if not r.slo_violated()]
        served_by_class = {g: sum(1 for r in done if r.vclass is not None
                                  and r.vclass.value == g)
                           for g in GROUPS[:3]}
        rejected_by_class = {g: sum(1 for r in rs
                                    if r.state is State.REJECTED
                                    and r.vclass is not None
                                    and r.vclass.value == g)
                             for g in GROUPS[:3]}
        out[t] = {
            "n": len(rs),
            "finished": len(done),
            "rejected": sum(r.state is State.REJECTED for r in rs),
            "slo_attainment": (len(ok) / len(rs)) if rs else 0.0,
            "goodput": len(ok) / duration if duration else 0.0,
            "served_by_class": served_by_class,
            "rejected_by_class": rejected_by_class,
        }
    return out


def summarize_fleet(fleet) -> dict:
    """Per-replica fleet-tier counters (ISSUE 9): lifecycle state,
    migrations attempted/succeeded/fallen-back, drain durations,
    repartition and health-transition events — the operator's view of a
    ``serving.fleet.Fleet`` run, surfaced in the fleet benchmark summary.
    Works on a plain ``Router`` too (fleet-only fields read as zero)."""
    n = len(fleet.engines)

    def _per(attr, default=0):
        v = getattr(fleet, attr, None)
        return v if v is not None else [default] * n

    states = getattr(fleet, "replica_state", None)
    mig_out, mig_in = _per("migrations_out"), _per("migrations_in")
    replicas = []
    for i, eng in enumerate(fleet.engines):
        replicas.append({
            "replica": i,
            "state": (states[i].value if states is not None
                      else ("alive" if fleet.alive[i] else "dead")),
            "alive": fleet.alive[i],
            "clock": eng.now,
            "finished": len(eng.finished),
            "migrations_out": mig_out[i],
            "migrations_in": mig_in[i],
            "used_pages": eng.allocator.used_pages,
            "pinned_encoder_entries": (
                eng.encoder_cache.stats()["pinned"]
                if eng.encoder_cache is not None else 0),
            "journal_records": (len(eng.journal)
                                if getattr(eng, "journal", None) is not None
                                else 0),
        })
    drains = getattr(fleet, "drain_events", [])
    return {
        "replicas": replicas,
        "migrations": {
            "attempted": getattr(fleet, "migrations_attempted", 0),
            "succeeded": getattr(fleet, "migrations_succeeded", 0),
            "fallbacks": getattr(fleet, "migration_fallbacks", 0),
            "noops": getattr(fleet, "migration_noops", 0),
            "retries": getattr(fleet, "migration_retries", 0),
            "pages_transferred": getattr(fleet, "migrated_pages", 0),
            "pages_deduped": getattr(fleet, "deduped_pages", 0),
        },
        "drain_events": drains,
        "drain_duration_avg": (sum(d["duration"] for d in drains)
                               / len(drains) if drains else 0.0),
        "repartition_events": getattr(fleet, "repartition_events", []),
        "health_events": getattr(fleet, "health_events", []),
        "kill_events": fleet.kill_events,
        "redispatched": fleet.redispatched,
        "lost": len(fleet.lost),
        # crash recovery (ISSUE 10): restart/rejoin history + the
        # journal-replay cross-check tally (zero-length fleet fields
        # when summarizing a plain Router)
        "restart_events": getattr(fleet, "restart_events", []),
        "journal_checks": getattr(fleet, "journal_checks", 0),
        "journal_mismatches": list(getattr(fleet, "journal_mismatches",
                                           [])),
    }


def rejection_mix(reqs: list[Request]) -> dict:
    """Rejected-request fractions by vehicle class: of all offered
    requests in a class, what share was refused at admission.  The
    benchmark asserts the modality-aware order — trucks refused at the
    highest rate, motorcycles at the lowest."""
    out = {}
    for g in GROUPS[:3]:
        rs = [r for r in reqs if r.vclass is not None and r.vclass.value == g]
        rej = sum(r.state is State.REJECTED for r in rs)
        out[g] = {"offered": len(rs), "rejected": rej,
                  "rate": rej / len(rs) if rs else 0.0}
    return out


def slo_attainment(reqs: list[Request]) -> float:
    """Fraction of ALL offered requests that finished within their SLO —
    rejections and failures count against attainment (the closed-loop
    quantity ROADMAP open item 3 asks for)."""
    if not reqs:
        return 0.0
    ok = sum(1 for r in reqs
             if r.state is State.FINISHED and not r.slo_violated())
    return ok / len(reqs)


def ttft_components(reqs: list[Request]) -> dict[str, float] | None:
    """Mean per-stage TTFT decomposition over finished requests: where did
    the time to first token actually go (encode-wait vs prefill-wait vs
    queue-wait; benchmarks/ttft_breakdown.py)."""
    parts = [bd for r in reqs if (bd := r.ttft_breakdown()) is not None]
    if not parts:
        return None
    n = len(parts)
    return {k: sum(p[k] for p in parts) / n for k in parts[0]}


def goodput(reqs: list[Request], duration: float | None = None) -> float:
    """Requests/second finishing within their SLO (paper Fig. 15)."""
    ok = [r for r in reqs if r.finish_time is not None and not r.slo_violated()]
    if not ok:
        return 0.0
    if duration is None:
        t0 = min(r.arrival for r in reqs)
        t1 = max(r.finish_time for r in reqs if r.finish_time is not None)
        duration = max(t1 - t0, 1e-9)
    return len(ok) / duration


def fmt_table(summary: dict, title: str = "") -> str:
    lines = []
    if title:
        lines.append(f"== {title} ==")
    hdr = f"{'class':<12}{'n':>5}{'TTFT avg':>10}{'TTFT p90':>10}" \
          f"{'norm lat':>10}{'SLO viol':>10}{'severity':>10}{'preempt':>9}"
    lines.append(hdr)
    for g in GROUPS:
        s = summary.get(g)
        if s is None:
            continue
        lines.append(
            f"{g:<12}{s['n']:>5}{s['ttft_avg']:>10.3f}{s['ttft_p90']:>10.3f}"
            f"{s['norm_latency_avg']:>10.4f}{s['slo_violation_rate']:>10.1%}"
            f"{s['violation_severity_avg']:>10.2f}{s['preemptions']:>9}")
    return "\n".join(lines)
