"""Aggregate serving metrics (the paper's reported quantities)."""
from __future__ import annotations

import numpy as np

from .request import Request, VehicleClass

GROUPS = ("motorcycle", "car", "truck", "overall")


def _group(reqs: list[Request], g: str) -> list[Request]:
    if g == "overall":
        return reqs
    return [r for r in reqs if r.vclass is not None and r.vclass.value == g]


def summarize(reqs: list[Request]) -> dict:
    """Per-class + overall: TTFT, normalized latency, SLO violation rate &
    severity, preemption counts/time (paper Figs. 3/8/10/11...)."""
    out = {}
    for g in GROUPS:
        rs = _group(reqs, g)
        if not rs:
            out[g] = None
            continue
        ttft = np.array([r.ttft() for r in rs if r.ttft() is not None])
        norm = np.array([r.norm_latency() for r in rs
                         if r.norm_latency() is not None])
        viol = np.array([r.slo_violated() for r in rs])
        sev = np.array([r.violation_severity() for r in rs if r.slo_violated()])
        out[g] = {
            "n": len(rs),
            "ttft_avg": float(ttft.mean()) if len(ttft) else float("nan"),
            "ttft_p90": float(np.percentile(ttft, 90)) if len(ttft) else float("nan"),
            "norm_latency_avg": float(norm.mean()) if len(norm) else float("nan"),
            "slo_violation_rate": float(viol.mean()) if len(viol) else 0.0,
            "violation_severity_avg": float(sev.mean()) if len(sev) else 0.0,
            "preemptions": int(sum(r.preemptions for r in rs)),
            "preempted_time": float(sum(r.preempted_time for r in rs)),
        }
    return out


def goodput(reqs: list[Request], duration: float | None = None) -> float:
    """Requests/second finishing within their SLO (paper Fig. 15)."""
    ok = [r for r in reqs if r.finish_time is not None and not r.slo_violated()]
    if not ok:
        return 0.0
    if duration is None:
        t0 = min(r.arrival for r in reqs)
        t1 = max(r.finish_time for r in reqs if r.finish_time is not None)
        duration = max(t1 - t0, 1e-9)
    return len(ok) / duration


def fmt_table(summary: dict, title: str = "") -> str:
    lines = []
    if title:
        lines.append(f"== {title} ==")
    hdr = f"{'class':<12}{'n':>5}{'TTFT avg':>10}{'TTFT p90':>10}" \
          f"{'norm lat':>10}{'SLO viol':>10}{'severity':>10}{'preempt':>9}"
    lines.append(hdr)
    for g in GROUPS:
        s = summary.get(g)
        if s is None:
            continue
        lines.append(
            f"{g:<12}{s['n']:>5}{s['ttft_avg']:>10.3f}{s['ttft_p90']:>10.3f}"
            f"{s['norm_latency_avg']:>10.4f}{s['slo_violation_rate']:>10.1%}"
            f"{s['violation_severity_avg']:>10.2f}{s['preemptions']:>9}")
    return "\n".join(lines)
