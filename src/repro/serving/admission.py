"""Overload control (ISSUE 8): SLO-aware admission + the brownout ladder.

Past the capacity knee, responsiveness is an *overload-control* problem,
not a scheduling one: an engine that accepts every arrival grows its
queues without bound and every SLO is eventually lost. This module gives
the serving tier one graded degradation policy with two halves:

``AdmissionController``
    Decides at ingest — deterministically, from engine state only, never
    from an RNG — whether a classified request can be served at all:

      * bounded per-class queue depth (rocks get the shortest queue,
        sand the longest: a queued rock is hours of work, a queued
        motorcycle is milliseconds);
      * per-tenant token buckets (prompt tokens as the budget currency;
        a bucket never goes negative — a request either fits or is
        refused whole);
      * an SLO feasibility test: predicted TTFT at admission — the
        executor's isolated-e2e estimate plus the backlog already
        queued/prefilling ahead of it — against the request's remaining
        SLO budget. The headroom is *modality-aware*: rocks are judged
        at 1x, pebbles and sand at increasingly lenient multipliers, so
        under pressure rocks are refused first and motorcycles last
        (the paper's abstraction applied to overload).

    A refused request enters the terminal ``REJECTED`` state through the
    engine's exactly-once release machinery (``Engine._abort``) — never
    FAILED/CANCELLED, visible separately in metrics.

``BrownoutLadder``
    Before any rejection, *sustained* pressure (admission blocked on KV
    pages) steps through graded service degradation:

      rung 1  ``encode``        shrink rock encode chunks (a truck's
                                per-iteration encode share is capped, so
                                pebble/sand encodes keep flowing);
      rung 2  ``defer_trucks``  stop admitting waiting trucks to prefill
                                (admitted trucks continue);
      rung 3  ``publication``   tighten prefix-cache publication (skip
                                popularity-gated index growth; preempted
                                victims still self-publish);
      top     shed              modality-aware load shedding — PR 6's
                                ``load_shed`` absorbed: one ladder, not
                                two pressure policies.

    Hysteresis: climbing takes ``step_iters`` consecutive pressure
    iterations per rung, descending takes ``cooldown_iters`` clean ones
    — the ladder cannot oscillate at a fixed boundary load, because one
    clean iteration resets the climb counter while descent needs a long
    clean streak. The legacy ``EngineConfig.load_shed`` knob maps onto a
    rung-free ladder (``rungs=()``, ``cooldown_iters=1``) that
    reproduces the PR 6 shed cadence exactly.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.serving.request import Request, VehicleClass

#: default feasibility headroom per class, in VehicleClass enum order
#: (motorcycle, car, truck): the knob that makes rejection modality-
#: aware. Rocks are judged conservatively (below their nominal budget):
#: admitting an infeasible truck strands minutes of GPU work that then
#: delays everything behind it, while an optimistically-admitted
#: motorcycle risks only milliseconds — so sand gets 2.5x slack and
#: rocks must clear 0.7x.
DEFAULT_HEADROOM = {
    VehicleClass.MOTORCYCLE: 2.5,
    VehicleClass.CAR: 1.2,
    VehicleClass.TRUCK: 0.7,
}

#: default bounded queue depth per class (None = unbounded). Rocks queue
#: shortest: each one parked is minutes of GPU work promised and not
#: started, which is exactly the backlog the feasibility test fights.
DEFAULT_QUEUE_DEPTH = {
    VehicleClass.MOTORCYCLE: 512,
    VehicleClass.CAR: 256,
    VehicleClass.TRUCK: 64,
}


@dataclass(frozen=True)
class TenantBudget:
    """One tenant's token-bucket parameters (prompt tokens as currency).
    The defaults are infinite — a tenant without an explicit budget is
    never refused for budget reasons."""
    rate: float = float("inf")    # tokens/second refill
    burst: float = float("inf")   # bucket capacity


class TokenBucket:
    """Classic token bucket on the engine's simulated clock. By
    construction the level can never go negative: ``take`` refuses any
    request the current level cannot cover whole."""
    __slots__ = ("rate", "burst", "level", "last", "min_level")

    def __init__(self, budget: TenantBudget, now: float):
        self.rate = budget.rate
        self.burst = budget.burst
        self.level = budget.burst
        self.last = now
        self.min_level = budget.burst

    def refill(self, now: float) -> None:
        if now > self.last and self.rate != float("inf"):
            self.level = min(self.burst,
                             self.level + self.rate * (now - self.last))
        self.last = max(self.last, now)

    def take(self, amount: float, now: float) -> bool:
        self.refill(now)
        if self.level == float("inf"):
            return True
        if amount > self.level:
            return False
        self.level -= amount
        self.min_level = min(self.min_level, self.level)
        return True


@dataclass(frozen=True)
class AdmissionConfig:
    """Knobs for the SLO-aware admission controller. The defaults are
    deliberately permissive: infinite tenant budgets, generous queue
    bounds, headroom >= 1 — an under-capacity run admits everything, so
    installing the layer is behaviour-identical until real pressure."""
    # SLO feasibility: predicted_ttft <= remaining_budget * headroom[class]
    slo_feasibility: bool = True
    headroom: dict = field(default_factory=lambda: dict(DEFAULT_HEADROOM))
    # each brownout level tightens the headroom by this fraction, so the
    # ladder and the admission gate are one escalating policy
    pressure_tighten: float = 0.25
    # backlog model: seconds of queued + in-flight prefill ahead of the
    # candidate, weighted (1.0 = trust the estimator sums as-is)
    backlog_weight: float = 1.0
    # bounded per-class queue depth (None disables the bound entirely)
    max_queue_depth: dict | None = field(
        default_factory=lambda: dict(DEFAULT_QUEUE_DEPTH))
    # per-tenant budgets; tenants not listed get ``default_budget``
    default_budget: TenantBudget = field(default_factory=TenantBudget)
    tenant_budgets: dict = field(default_factory=dict)


class AdmissionController:
    """Deterministic per-request admit/reject decisions at ingest.

    Stateful only through the tenant buckets and counters; every
    decision is a pure function of (request, engine state, clock), so a
    replayed workload re-derives the identical rejection set."""

    def __init__(self, cfg: AdmissionConfig):
        self.cfg = cfg
        self.buckets: dict[str, TokenBucket] = {}
        self.admitted = 0
        self.rejections: dict[str, int] = {}   # reason -> count

    # -- accounting --------------------------------------------------------
    def _reject(self, reason: str) -> str:
        self.rejections[reason] = self.rejections.get(reason, 0) + 1
        return reason

    def min_bucket_level(self) -> float:
        """Lowest level any tenant bucket ever reached (gate: >= 0)."""
        if not self.buckets:
            return float("inf")
        return min(b.min_level for b in self.buckets.values())

    def bucket_for(self, tenant: str, now: float) -> TokenBucket:
        b = self.buckets.get(tenant)
        if b is None:
            budget = self.cfg.tenant_budgets.get(
                tenant, self.cfg.default_budget)
            b = TokenBucket(budget, now)
            self.buckets[tenant] = b
        return b

    # -- the feasibility model --------------------------------------------
    #: classes whose backlog runs ahead of (or alongside) each class
    #: under TCM's sand-first discipline: a motorcycle only waits behind
    #: other motorcycles; a truck waits behind everything. Class-blind
    #: backlog would invert the rejection order — sand's absolute SLO
    #: budget is tiny, so charging it the trucks' queue rejects
    #: motorcycles first, the exact opposite of the paper's abstraction.
    _AHEAD = {
        VehicleClass.MOTORCYCLE: (VehicleClass.MOTORCYCLE,),
        VehicleClass.CAR: (VehicleClass.MOTORCYCLE, VehicleClass.CAR),
        VehicleClass.TRUCK: tuple(VehicleClass),
    }

    def predict_ttft(self, req: Request, engine) -> float:
        """Predicted time to first token if admitted now: the isolated
        e2e estimate plus every second of estimated prefill that will be
        scheduled ahead of this request — queued or in-flight work of
        the classes TCM serves at or above this request's priority."""
        ahead = self._AHEAD[req.vclass]
        backlog = sum(engine.queues.queues[c].est_prefill_sum
                      for c in ahead)
        backlog += sum(engine.encode_queues.queues[c].est_prefill_sum
                       for c in ahead)
        for r in engine.prefilling:
            if r.vclass in ahead and r.prompt_tokens > 0:
                backlog += r.est_prefill * \
                    (1.0 - r.prefilled / r.prompt_tokens)
        return (self.cfg.backlog_weight * backlog
                + engine.executor.isolated_e2e(req))

    # -- the decision ------------------------------------------------------
    def decide(self, req: Request, engine) -> str | None:
        """None = admit; otherwise the (deterministic) rejection reason.
        Order matters: cheap structural bounds first, the feasibility
        model second, and the tenant bucket last — a request that could
        never run must not drain its tenant's budget."""
        cfg = self.cfg
        now = engine.now
        if cfg.max_queue_depth is not None:
            cap = cfg.max_queue_depth.get(req.vclass)
            if cap is not None:
                depth = (len(engine.queues.queues[req.vclass])
                         + len(engine.encode_queues.queues[req.vclass]))
                if depth >= cap:
                    return self._reject(
                        f"admission: {req.vclass.value} queue full "
                        f"({depth}/{cap})")
        if cfg.slo_feasibility and req.slo != float("inf"):
            headroom = cfg.headroom.get(req.vclass, 1.0)
            level = engine.ladder.level if engine.ladder is not None else 0
            headroom /= (1.0 + level * cfg.pressure_tighten)
            budget = req.slo - (now - req.arrival)
            predicted = self.predict_ttft(req, engine)
            if predicted > budget * headroom:
                return self._reject(
                    f"admission: SLO infeasible (predicted TTFT "
                    f"{predicted:.2f}s > {budget:.2f}s x "
                    f"{headroom:.2f} {req.vclass.value} headroom)")
        if not self.bucket_for(req.tenant, now).take(req.prompt_tokens, now):
            return self._reject(
                f"admission: tenant {req.tenant} budget exhausted")
        self.admitted += 1
        return None

    def describe(self) -> dict:
        return {
            "admitted": self.admitted,
            "rejections": dict(self.rejections),
            "min_bucket_level": self.min_bucket_level(),
            "tenants_seen": sorted(self.buckets),
        }


@dataclass(frozen=True)
class BrownoutConfig:
    """Hysteresis + rung set for the brownout ladder. ``rungs`` are the
    graded degradations climbed in order under sustained pressure; the
    shed stage sits above the last rung (enable with ``shed=True``).
    An empty ``rungs`` tuple with ``shed=True`` and ``cooldown_iters=1``
    is exactly PR 6's ``load_shed`` behaviour (the legacy mapping)."""
    step_iters: int = 20        # pressure iterations to climb one rung
    cooldown_iters: int = 60    # clean iterations to descend one rung
    rungs: tuple = ("encode", "defer_trucks", "publication")
    shed: bool = True
    # rung "encode": cap a truck's per-iteration encode chunk at this
    # fraction of the configured encode budget
    encode_chunk_frac: float = 0.25


class BrownoutLadder:
    """Graded-degradation state machine driven once per engine iteration
    by the page-pressure signal (``observe``). ``level`` counts active
    rungs; at the top, ``observe`` returning True asks the engine to
    shed one waiting rock (the engine confirms via ``shed_fired`` so an
    un-sheddable iteration — no rock waiting — retries immediately,
    matching the PR 6 cadence bit-for-bit under the legacy mapping)."""

    def __init__(self, cfg: BrownoutConfig):
        self.cfg = cfg
        self.level = 0
        self.transitions = 0     # climb+descend count (hysteresis gauge)
        self._up = 0             # consecutive pressure iterations
        self._down = 0           # consecutive clean iterations

    def active(self, rung: str) -> bool:
        """Is the named degradation currently engaged?"""
        rungs = self.cfg.rungs
        return rung in rungs and self.level > rungs.index(rung)

    def observe(self, pressure: bool) -> bool:
        """Advance the hysteresis counters; True = shed one request."""
        cfg = self.cfg
        if pressure:
            self._down = 0
            self._up += 1
            if self.level < len(cfg.rungs):
                if self._up >= cfg.step_iters:
                    self.level += 1
                    self.transitions += 1
                    self._up = 0
                return False
            return cfg.shed and self._up >= cfg.step_iters
        self._up = 0
        self._down += 1
        if self._down >= cfg.cooldown_iters and self.level > 0:
            self.level -= 1
            self.transitions += 1
            self._down = 0
        return False

    def shed_fired(self) -> None:
        """A shed actually happened: half-reset the streak so continued
        pressure sheds gradually (one rock per step_iters//2 pressured
        iterations), not one per iteration."""
        self._up = self.cfg.step_iters // 2

    def describe(self) -> dict:
        return {"level": self.level, "rungs": list(self.cfg.rungs),
                "transitions": self.transitions}


def legacy_shed_config(shed_after_iters: int) -> BrownoutConfig:
    """PR 6's ``load_shed`` expressed as a ladder: no graded rungs, shed
    at ``shed_after_iters`` of sustained pressure, full reset on any
    clean iteration (cooldown 1 — there is no level to hold)."""
    return BrownoutConfig(step_iters=shed_after_iters, cooldown_iters=1,
                          rungs=(), shed=True)
