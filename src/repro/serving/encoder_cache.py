"""Encoder-output cache — the "pebble cache" (ISSUE 2 tentpole).

Vision-encoder outputs are pure functions of the mm input, and real
traffic repeats inputs (the same image re-asked with a new question,
thumbnails, shared attachments). The engine keys encoder outputs by a
content hash of the mm payload (``Request.mm_hash``): a hit skips the
ENCODING stage entirely — the request goes straight to the prefill queue
with its embeddings "already resident" — which can only improve TTFT,
never change outputs (tests/test_encode_pipeline.py property-tests both).

The sim tracks presence only; a real deployment would pin the embedding
tensors (mm_units x d_model) and account their HBM against the KV budget.
LRU eviction bounds that footprint. ``WorkloadConfig.duplicate_prob``
exercises the cache with controlled input reuse.
"""
from __future__ import annotations

from collections import OrderedDict


class EncoderCache:
    """LRU over mm-content hashes with hit/miss accounting.

    Entries can be **pinned** (ref-counted): while any in-flight request
    depends on an entry — it hit the cache at ingest, or is mid-encode and
    will insert/share it — LRU eviction must never drop it (in a real
    deployment the embeddings would vanish under the request). Pins are
    keyed by hash and may precede the insert (a request in
    ``State.ENCODING`` reserves its hash before the output lands); the
    cache may transiently exceed ``capacity`` when everything resident is
    pinned, bounded by the number of in-flight mm requests.
    """

    __slots__ = ("capacity", "hits", "misses", "insertions", "evictions",
                 "_lru", "_pins")

    def __init__(self, capacity: int = 256):
        if capacity <= 0:
            raise ValueError("EncoderCache capacity must be positive")
        self.capacity = capacity
        self.hits = 0
        self.misses = 0
        self.insertions = 0
        self.evictions = 0
        self._lru: OrderedDict[str, int] = OrderedDict()  # hash -> mm_units
        self._pins: dict[str, int] = {}                   # hash -> refcount

    def __len__(self) -> int:
        return len(self._lru)

    def __contains__(self, key: str) -> bool:
        return key in self._lru

    def lookup(self, key: str) -> bool:
        """Consult the cache for one request's mm input (counts the
        hit/miss); a hit refreshes the entry's recency."""
        if key in self._lru:
            self._lru.move_to_end(key)
            self.hits += 1
            return True
        self.misses += 1
        return False

    def insert(self, key: str, mm_units: int = 0) -> None:
        """Record a freshly-encoded input; evicts LRU beyond capacity
        (pinned entries are skipped — the cache runs over capacity rather
        than drop an entry someone is mid-flight on). Re-inserting an
        existing key only refreshes recency."""
        if key in self._lru:
            self._lru.move_to_end(key)
            return
        self._lru[key] = mm_units
        self.insertions += 1
        over = len(self._lru) - self.capacity
        if over > 0:
            for victim in [k for k in self._lru
                           if k not in self._pins][:over]:
                del self._lru[victim]
                self.evictions += 1

    # -- pinning (ISSUE 6 satellite) --------------------------------------
    def pin(self, key: str) -> None:
        """Ref-count a dependency on ``key``. Valid before the insert
        (mid-encode reservation) as well as after (ingest hit)."""
        self._pins[key] = self._pins.get(key, 0) + 1

    def unpin(self, key: str) -> None:
        n = self._pins.get(key, 0) - 1
        assert n >= 0, f"unpin of never-pinned encoder-cache key {key!r}"
        if n == 0:
            del self._pins[key]
        else:
            self._pins[key] = n

    def pin_count(self, key: str) -> int:
        return self._pins.get(key, 0)

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "entries": len(self._lru),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "insertions": self.insertions,
            "evictions": self.evictions,
            "pinned": len(self._pins),
            "pin_refs": sum(self._pins.values()),
        }
