"""Encoder-output cache — the "pebble cache" (ISSUE 2 tentpole).

Vision-encoder outputs are pure functions of the mm input, and real
traffic repeats inputs (the same image re-asked with a new question,
thumbnails, shared attachments). The engine keys encoder outputs by a
content hash of the mm payload (``Request.mm_hash``): a hit skips the
ENCODING stage entirely — the request goes straight to the prefill queue
with its embeddings "already resident" — which can only improve TTFT,
never change outputs (tests/test_encode_pipeline.py property-tests both).

The sim tracks presence only; a real deployment would pin the embedding
tensors (mm_units x d_model) and account their HBM against the KV budget.
LRU eviction bounds that footprint. ``WorkloadConfig.duplicate_prob``
exercises the cache with controlled input reuse.
"""
from __future__ import annotations

from collections import OrderedDict


class EncoderCache:
    """LRU over mm-content hashes with hit/miss accounting."""

    __slots__ = ("capacity", "hits", "misses", "insertions", "evictions",
                 "_lru")

    def __init__(self, capacity: int = 256):
        if capacity <= 0:
            raise ValueError("EncoderCache capacity must be positive")
        self.capacity = capacity
        self.hits = 0
        self.misses = 0
        self.insertions = 0
        self.evictions = 0
        self._lru: OrderedDict[str, int] = OrderedDict()  # hash -> mm_units

    def __len__(self) -> int:
        return len(self._lru)

    def __contains__(self, key: str) -> bool:
        return key in self._lru

    def lookup(self, key: str) -> bool:
        """Consult the cache for one request's mm input (counts the
        hit/miss); a hit refreshes the entry's recency."""
        if key in self._lru:
            self._lru.move_to_end(key)
            self.hits += 1
            return True
        self.misses += 1
        return False

    def insert(self, key: str, mm_units: int = 0) -> None:
        """Record a freshly-encoded input; evicts LRU beyond capacity.
        Re-inserting an existing key only refreshes recency."""
        if key in self._lru:
            self._lru.move_to_end(key)
            return
        self._lru[key] = mm_units
        self.insertions += 1
        while len(self._lru) > self.capacity:
            self._lru.popitem(last=False)
            self.evictions += 1

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "entries": len(self._lru),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "insertions": self.insertions,
            "evictions": self.evictions,
        }
