"""Append-only per-replica lifecycle journal + pure replay oracle.

Every replica (``EngineConfig.journal=True``) records each request state
transition, KV page acquisition/release, encoder-cache pin/unpin, and
fleet handoff (export / migration) as an immutable tuple. ``replay``
folds the log into terminal states and resource accounting **without
consulting any live engine state** — a second, independent derivation of
what the engine's allocator and pin table must now contain. The fleet
cross-checks the two bit-exactly at every kill, drain completion, and
end of run (``verify_engine``): a divergence means either a resource
release was missed/doubled on the live path or a record was dropped on
the journal path — both are real bugs, so this is a runtime correctness
checker, not a debug aid.

Recovery uses the same log: when a replica crashes, ``replay(...).
inflight`` is the exact set of requests whose fate the dead replica
still owed — known stage at crash, so the fleet re-dispatches them for
residual re-prefill while everything already terminal (or already
exported to another replica) is excluded and can never double-finish.

Recording is pure observation: hooks are gated on ``journal is not
None``, touch no RNG and no clock, and allocate nothing the engine
reads back — a journal-enabled run is bit-identical to the same run
without it (benchmarks/recovery.py gates this against the PR 9
``Fleet`` == ``Router`` baseline).

Record schema (see DESIGN.md §Recovery & lifecycle journal)::

    (seq, now, kind, rid, data)

    kind        data                     meaning
    ---------   ----------------------   --------------------------------
    state       stage name (str)         entered WAITING/ENCODING/
                                         PREFILLING/RUNNING/PREEMPTED
    terminal    terminal state (str)     entered FINISHED/REJECTED/
                                         FAILED/CANCELLED
    acquire     tuple of page ids        pages appended to the rid's
                                         block table (claim + fresh)
    release     None                     the rid's whole page list freed
    pin         mm_hash (str)            encoder-cache entry pinned
    unpin       mm_hash (str)            that pin released
    export      None                     non-terminal handoff off this
                                         replica (drain/migration/kill)
    migrate_in  page count (int)         page-chain import landed here
                                         (informational; pages enter the
                                         cache, not the rid's ownership)
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class Journal:
    """The append-only log one engine writes. ``record`` is the only
    mutation; everything else reads ``records`` as immutable history."""
    records: list[tuple] = field(default_factory=list)

    def record(self, now: float, kind: str, rid: str,
               data=None) -> None:
        self.records.append((len(self.records), now, kind, rid, data))

    def __len__(self) -> int:
        return len(self.records)


@dataclass
class ReplayState:
    """What a pure left-fold of the journal says the engine must hold."""
    terminal: dict[str, str] = field(default_factory=dict)
    owned: dict[str, list[int]] = field(default_factory=dict)
    pins: dict[str, str] = field(default_factory=dict)
    stage: dict[str, str] = field(default_factory=dict)
    exported: set[str] = field(default_factory=set)

    @property
    def inflight(self) -> set[str]:
        """Requests this replica still owes a fate: ingested here, not
        terminal, and not handed off to another replica."""
        return {rid for rid in self.stage
                if rid not in self.terminal and rid not in self.exported}


def replay(records) -> ReplayState:
    """Pure fold of journal records into reconstructed accounting.

    Ordering invariants the engine's hooks guarantee (and this relies
    on): ``release`` precedes the ``terminal``/``export`` record of the
    same transition; a re-ingested rid (exported away, later migrated
    back) opens with a fresh ``state`` record, which clears its exported
    mark — the replica owes it a fate again.
    """
    st = ReplayState()
    for _seq, _now, kind, rid, data in records:
        if kind == "state":
            st.stage[rid] = data
            st.exported.discard(rid)
        elif kind == "terminal":
            st.terminal[rid] = data
        elif kind == "acquire":
            st.owned.setdefault(rid, []).extend(data)
        elif kind == "release":
            st.owned.pop(rid, None)
        elif kind == "pin":
            st.pins[rid] = data
        elif kind == "unpin":
            st.pins.pop(rid, None)
        elif kind == "export":
            st.exported.add(rid)
        # migrate_in (and any future informational kind): no-op
    return st


def verify_engine(engine) -> list[str]:
    """Cross-check the replayed accounting against the live engine
    bit-exactly. Returns human-readable mismatch strings (empty = the
    two independent derivations agree). Compares:

      * terminal partition: replayed terminal map vs the engine's
        finished/rejected/aborted lists (same rids, same states);
      * page ownership: replayed block tables vs the allocator's
        ``owned_map()`` — same rids, same pages, same order;
      * encoder pins: replayed pin table vs ``engine._enc_pins``.
    """
    if engine.journal is None:
        return []
    st = replay(engine.journal.records)
    out: list[str] = []
    live_terminal = {r.rid: r.state.value
                     for r in (engine.finished + engine.rejected
                               + engine.aborted)}
    if st.terminal != live_terminal:
        only_live = {k: v for k, v in live_terminal.items()
                     if st.terminal.get(k) != v}
        only_replay = {k: v for k, v in st.terminal.items()
                       if live_terminal.get(k) != v}
        out.append(f"terminal mismatch: live-only {only_live!r} "
                   f"replay-only {only_replay!r}")
    live_owned = engine.allocator.owned_map()
    replay_owned = {rid: tuple(ps) for rid, ps in st.owned.items() if ps}
    if replay_owned != live_owned:
        only_live = {k: v for k, v in live_owned.items()
                     if replay_owned.get(k) != v}
        only_replay = {k: v for k, v in replay_owned.items()
                       if live_owned.get(k) != v}
        out.append(f"owned-pages mismatch: live-only {only_live!r} "
                   f"replay-only {only_replay!r}")
    live_pins = dict(engine._enc_pins)
    if st.pins != live_pins:
        out.append(f"encoder-pin mismatch: live {live_pins!r} "
                   f"replay {st.pins!r}")
    return out
