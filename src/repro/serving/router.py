"""Multi-replica serving router (beyond-paper: the paper's §4.4 lists
multi-GPU/multi-node scaling as future work).

Each replica is a full TCM engine (own scheduler, KV allocator, executor).
The router assigns requests at arrival:

  * round-robin      — baseline.
  * least-loaded     — by outstanding estimated prefill seconds.
  * truck-isolation  — modality-aware placement: trucks (and spillover
    cars) are pinned to a dedicated subset of replicas so motorcycles get
    contention-free replicas — the scheduling-level analogue of ModServe's
    stage disaggregation, built on TCM's own classifier.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.scheduler import make_policy
from repro.serving.engine import Engine, EngineConfig
from repro.serving.request import Request, VehicleClass


@dataclass
class Router:
    executors: list            # one per replica
    classifier: object
    engine_cfg: EngineConfig
    policy: str = "tcm"        # per-replica scheduling policy
    routing: str = "least-loaded"
    truck_replicas: int = 1    # for truck-isolation: replicas reserved

    def __post_init__(self):
        self.engines = [Engine(make_policy(self.policy), ex, self.classifier,
                               self.engine_cfg) for ex in self.executors]
        self._rr = 0
        self._load = [0.0] * len(self.engines)

    # ------------------------------------------------------------------
    def _route(self, req: Request) -> int:
        n = len(self.engines)
        if self.routing == "round-robin":
            # return the current cursor, THEN advance — incrementing first
            # skipped replica 0 on the first assignment and started every
            # run load-skewed
            i = self._rr
            self._rr = (self._rr + 1) % n
            return i
        vclass, est_prefill, _ = self.classifier.classify(
            req.modality.value, req.text_tokens, req.mm_units)
        if self.routing == "least-loaded":
            i = min(range(n), key=lambda j: self._load[j])
            self._load[i] += est_prefill
            return i
        if self.routing == "truck-isolation":
            heavy = set(range(n - self.truck_replicas, n))
            light = [j for j in range(n) if j not in heavy]
            if vclass is VehicleClass.TRUCK:
                pool = sorted(heavy)
            elif vclass is VehicleClass.CAR:
                pool = light + sorted(heavy)   # cars spill to heavy replicas
            else:
                pool = light
            i = min(pool, key=lambda j: self._load[j])
            self._load[i] += est_prefill
            return i
        raise ValueError(self.routing)

    # ------------------------------------------------------------------
    def run(self, requests: list[Request]) -> list[Request]:
        buckets: list[list[Request]] = [[] for _ in self.engines]
        for req in sorted(requests, key=lambda r: r.arrival):
            buckets[self._route(req)].append(req)
        done: list[Request] = []
        for eng, bucket in zip(self.engines, buckets):
            done.extend(eng.run(bucket))
        return done
