"""Multi-replica serving router (beyond-paper: the paper's §4.4 lists
multi-GPU/multi-node scaling as future work).

Each replica is a full TCM engine (own scheduler, KV allocator, executor).
The router assigns requests at arrival:

  * round-robin      — baseline.
  * least-loaded     — by outstanding estimated prefill seconds.
  * truck-isolation  — modality-aware placement: trucks (and spillover
    cars) are pinned to a dedicated subset of replicas so motorcycles get
    contention-free replicas — the scheduling-level analogue of ModServe's
    stage disaggregation, built on TCM's own classifier.
  * prefix-aware     — place where the replica's KV prefix cache already
    holds the longest match for the request's content (tie: least load),
    so duplicate rocks land where their pages are (ISSUE 6).
  * pressure-aware   — overload-control routing (ISSUE 8): prefer the
    replica lowest on its brownout ladder (see serving/admission.py),
    breaking ties by outstanding load — arrivals drain away from
    replicas that are browning out before their admission controllers
    start rejecting, the fleet-scale hook the ROADMAP's open item
    anticipates.

Failover (ISSUE 6 tentpole): ``run_stepped`` co-simulates every replica
on one timeline, applies whole-replica crashes from the fault plan's
``replica_kills`` schedule, and re-dispatches each dead replica's
in-flight (and still-pending) requests to surviving replicas —
prefix-cache-aware, so re-dispatched work re-claims any pages a survivor
already holds for the same content. A crash loses the replica's memory
(KV, encoder cache, progress); requests restart from scratch via
``Request.reset_for_redispatch`` — none lost, none double-finished.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.scheduler import make_policy
from repro.serving.engine import Engine, EngineConfig
from repro.serving.request import Request, VehicleClass


@dataclass
class Router:
    executors: list            # one per replica
    classifier: object
    engine_cfg: EngineConfig
    policy: str = "tcm"        # per-replica scheduling policy
    routing: str = "least-loaded"
    truck_replicas: int = 1    # for truck-isolation: replicas reserved
    # fault plan shared by every replica (serving/faults.py) or None.
    # Replica kills only take effect under ``run_stepped``; per-request
    # faults key off rid/content so sharing one plan stays deterministic.
    faults: object | None = None

    def __post_init__(self):
        self.engines = [Engine(make_policy(self.policy), ex, self.classifier,
                               self.engine_cfg, faults=self.faults)
                        for ex in self.executors]
        self._rr = 0
        self._load = [0.0] * len(self.engines)
        # health tracking + failover accounting (ISSUE 6)
        self.alive = [True] * len(self.engines)
        self.killed_at: list[float | None] = [None] * len(self.engines)
        self._assigned: list[list[Request]] = [[] for _ in self.engines]
        self.kill_events: list[dict] = []
        self.redispatched = 0
        self.lost: list[Request] = []

    # ------------------------------------------------------------------
    def _route(self, req: Request) -> int:
        n = len(self.engines)
        if self.routing == "round-robin":
            # return the current cursor, THEN advance — incrementing first
            # skipped replica 0 on the first assignment and started every
            # run load-skewed
            i = self._rr
            self._rr = (self._rr + 1) % n
            return i
        vclass, est_prefill, _ = self.classifier.classify(
            req.modality.value, req.text_tokens, req.mm_units)
        if self.routing == "least-loaded":
            i = min(range(n), key=lambda j: self._load[j])
            self._load[i] += est_prefill
            return i
        if self.routing == "truck-isolation":
            heavy = set(range(n - self.truck_replicas, n))
            light = [j for j in range(n) if j not in heavy]
            if vclass is VehicleClass.TRUCK:
                pool = sorted(heavy)
            elif vclass is VehicleClass.CAR:
                pool = light + sorted(heavy)   # cars spill to heavy replicas
            else:
                pool = light
            i = min(pool, key=lambda j: self._load[j])
            self._load[i] += est_prefill
            return i
        if self.routing == "prefix-aware":
            i = self._prefix_target(req)
            self._load[i] += est_prefill
            return i
        if self.routing == "pressure-aware":
            pool = [j for j in range(n) if self.alive[j]] or list(range(n))
            i = min(pool, key=lambda j: (
                self.engines[j].ladder.level
                if self.engines[j].ladder is not None else 0,
                self._load[j]))
            self._load[i] += est_prefill
            return i
        raise ValueError(self.routing)

    def _redispatch_pool(self) -> list[int]:
        """Replicas eligible to receive re-dispatched/migrated work.
        The base router accepts any alive replica; the fleet tier
        (serving/fleet.py) also excludes draining ones."""
        return [j for j in range(len(self.engines)) if self.alive[j]]

    def _prefix_target(self, req: Request) -> int:
        """Eligible replica whose KV prefix cache matches the most tokens
        of this request's content (ties break toward the least-loaded)."""
        pool = self._redispatch_pool()
        limit = max(req.prompt_tokens - 1, 0)
        return max(pool, key=lambda j: (
            self.engines[j].allocator.match_prefix(
                req.content_chunks(), limit).tokens,
            -self._load[j]))

    # ------------------------------------------------------------------
    def run(self, requests: list[Request]) -> list[Request]:
        buckets: list[list[Request]] = [[] for _ in self.engines]
        for req in sorted(requests, key=lambda r: r.arrival):
            buckets[self._route(req)].append(req)
        done: list[Request] = []
        for eng, bucket in zip(self.engines, buckets):
            done.extend(eng.run(bucket))
        return done

    # -- failover co-simulation (ISSUE 6) ------------------------------
    def _kill(self, i: int, remaining: list[list[Request]]) -> None:
        """Replica crash: its memory (KV, encoder cache, all request
        progress) is gone. Every non-terminal request assigned to it —
        in-flight or still pending — restarts from scratch on the best
        surviving replica (prefix-aware: a survivor may already hold
        pages for the same content)."""
        eng = self.engines[i]
        self.alive[i] = False
        self.killed_at[i] = eng.now
        inflight = [r for r in self._assigned[i] if not r.is_terminal]
        self._assigned[i] = [r for r in self._assigned[i] if r.is_terminal]
        remaining[i] = []
        moved = 0
        for req in inflight:
            # release the dead engine's side of the request exactly once
            # (queue slots, KV pages, encoder-cache pins, executor memos)
            # BEFORE resetting it — a crashed replica's caches must audit
            # clean (zero pins, zero used pages), and ENCODING requests
            # otherwise leaked their encoder pin forever (ISSUE 9)
            eng.export_request(req)
            req.reset_for_redispatch()
            if not any(self.alive):
                self.lost.append(req)
                continue
            j = self._prefix_target(req)
            self._load[j] += req.est_prefill
            remaining[j].append(req)
            self._assigned[j].append(req)
            moved += 1
        for lst in remaining:
            lst.sort(key=lambda r: r.arrival)
        self.redispatched += moved
        self.kill_events.append(
            {"replica": i, "time": eng.now, "redispatched": moved})

    # -- stepped co-simulation hooks (overridden by serving/fleet.py) --
    def _dispatch_arrivals(self, reqs_sorted: list[Request],
                           remaining: list[list[Request]]) -> list[Request]:
        """Route the (arrival-sorted) workload into per-replica pending
        lists. The base router routes everything up-front and keeps no
        deferred pool; the fleet tier defers routing to arrival time so
        elastic repartitions can steer traffic mid-run. Returns the
        not-yet-routed tail (always empty here)."""
        for req in reqs_sorted:
            i = self._route(req)
            remaining[i].append(req)
            self._assigned[i].append(req)
        return []

    def _fleet_tick(self, pending: list[Request],
                    remaining: list[list[Request]]) -> list[Request]:
        """Per-outer-step fleet-tier hook (deferred routing, drains,
        health scoring, elastic repartitioning). No-op in the base
        router — which is exactly what keeps the fleet tier's
        no-events timeline bit-identical to this one."""
        return pending

    def _revivable(self) -> bool:
        """Whether a fully-idle/dead fleet can still come back (armed
        restarts, fleet tier). The base router's replicas never return,
        so an empty live set always ends the run."""
        return False

    def _next_arrival(self, i: int, pending: list[Request],
                      remaining: list[list[Request]]) -> float | None:
        """Earliest arrival that could still reach replica ``i`` (the
        idle-victim kill check must not let an idle clock jump a
        scheduled crash). The fleet tier also counts unrouted pending
        arrivals, any of which might route here."""
        return remaining[i][0].arrival if remaining[i] else None

    def run_stepped(self, requests: list[Request],
                    max_steps: int = 2_000_000) -> list[Request]:
        """Co-simulate all replicas step-by-step on one timeline: each
        outer step advances the alive replica whose clock lags furthest
        behind, and replica kills scheduled in the fault plan fire when
        the victim's clock reaches the kill time (an idle victim whose
        next arrival lies past the kill time dies in place — its clock
        would otherwise jump the crash)."""
        n = len(self.engines)
        remaining: list[list[Request]] = [[] for _ in range(n)]
        pending = self._dispatch_arrivals(
            sorted(requests, key=lambda r: r.arrival), remaining)
        for _ in range(max_steps):
            pending = self._fleet_tick(pending, remaining)
            if self.faults is not None:
                for i, eng in enumerate(self.engines):
                    if not self.alive[i]:
                        continue
                    if self.killed_at[i] is not None:
                        # already died once and was restarted (fleet tier,
                        # ISSUE 10): the kill schedule must not re-fire on
                        # the fresh engine. No-op for the base router
                        # (alive stays False after a kill).
                        continue
                    kt = self.faults.kill_time(i)
                    if kt is None:
                        continue
                    nxt = self._next_arrival(i, pending, remaining)
                    if eng.now >= kt or (eng.idle and
                                         (nxt is None or nxt > kt)):
                        self._kill(i, remaining)
            live = [i for i in range(n) if self.alive[i]
                    and (not self.engines[i].idle or remaining[i])]
            if not live:
                if self._revivable():
                    # armed restarts (fleet tier): the next _fleet_tick
                    # fires them by jumping to their scheduled time
                    continue
                break
            i = min(live, key=lambda j: self.engines[j].now)
            remaining[i] = self.engines[i].step(remaining[i])
        return [r for eng in self.engines for r in eng.finished]
