"""Multimodal workload generation (paper §4.1).

Poisson arrivals; three mixes:
  T0 — text-only; ML — light multimodal; MH — heavy multimodal.

Per-modality size distributions are calibrated to the paper's
characterization (Fig. 2, LLaVA-7B-like):
  * text  — highly diverse, 10..10^4 prompt tokens (lognormal), ShareGPT-like
  * image — near-constant patch counts (fixed vision tokenization, ~576
    patches +/- resizing jitter), LLaVA-Instruct-like
  * video — uniformly-sampled frames x patches/frame, 10^3..>10^5 tokens,
    LLaVA-Video-like heavy tail
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .request import Modality, Request

MIXES = {
    "T0": {"text": 1.0, "image": 0.0, "video": 0.0},
    "ML": {"text": 0.85, "image": 0.10, "video": 0.05},
    "MH": {"text": 0.50, "image": 0.30, "video": 0.20},
    # long-context video: most requests are rocks whose prompts sit near
    # the context cap (see long_context_video below) — the regime where
    # ragged paged geometry matters, since a fixed-width block table
    # charges the co-scheduled sand these rocks' context price
    "LCV": {"text": 0.30, "image": 0.10, "video": 0.60},
}


@dataclass
class WorkloadConfig:
    mix: str = "MH"
    rate: float = 2.0           # requests/second (Poisson)
    num_requests: int = 300
    seed: int = 0
    # dataset knobs
    text_tokens_log_mu: float = 5.3     # ~200 median
    text_tokens_log_sigma: float = 1.3
    image_patches: int = 576            # fixed vision tokenization
    image_patch_jitter: float = 0.15
    video_frames_min: int = 8
    video_frames_max: int = 64
    video_patches_per_frame: int = 196
    out_tokens_log_mu: float = 4.2      # ~67 median output tokens
    out_tokens_log_sigma: float = 0.8
    # P(an mm input repeats an earlier one of the same modality) —
    # exercises the engine's encoder-output cache. 0.0 keeps the RNG
    # stream identical to the historical generator (seeded workloads and
    # committed baselines are unchanged).
    duplicate_prob: float = 0.0
    # P(a text request opens with one of `shared_prefix_pool` fixed
    # system prompts) — exercises the KV prefix cache with page-aligned
    # shared text prefixes. Like duplicate_prob, 0.0 draws nothing from
    # the RNG, so seeded workloads and committed BENCH_*.json streams
    # stay byte-identical.
    shared_prefix_prob: float = 0.0
    shared_prefix_pool: int = 4
    shared_prefix_tokens_min: int = 64
    shared_prefix_tokens_max: int = 256
    # ---- trace-shaped generation (ISSUE 8, ServeGen-style) ----
    # All knobs default off and draw from a SEPARATE RNG stream, so the
    # base stream's draws — and every committed BENCH_*.json baseline —
    # stay byte-identical while the knobs are at their defaults.
    # Heavy-tailed lengths: with this probability a request's text /
    # output length is redrawn from a Pareto tail instead of the
    # lognormal body (production prompt-length CCDFs are power-law)
    heavy_tail_prob: float = 0.0
    heavy_tail_alpha: float = 1.6
    heavy_tail_text_cap: int = 32768
    heavy_tail_out_cap: int = 4096
    # Diurnal rate curve: rate(t) = rate * (1 + A*sin(2*pi*t/period)),
    # applied by rescaling the base stream's exponential gaps
    diurnal_amplitude: float = 0.0
    diurnal_period_s: float = 600.0
    # Burst windows: with this probability (checked per arrival outside
    # a burst) a burst starts, multiplying the rate by burst_factor for
    # burst_len_s seconds
    burst_prob: float = 0.0
    burst_factor: float = 4.0
    burst_len_s: float = 5.0
    # Multi-tenant client pool: > 0 assigns each request to one of N
    # tenants with zipf-skewed popularity; each tenant has a distinct
    # modality mix (interpolated text-heavy -> video-heavy around the
    # base mix) and its own shared system prompt (feeding the KV prefix
    # cache realistically). 0 = single "default" tenant.
    tenants: int = 0
    tenant_zipf_a: float = 1.2
    tenant_sys_prob: float = 0.75
    tenant_sys_tokens_min: int = 64
    tenant_sys_tokens_max: int = 256


def _shape_arrivals(cfg: WorkloadConfig, gaps: np.ndarray,
                    trng: np.random.Generator) -> np.ndarray:
    """Diurnal + burst arrival shaping: the base stream's exponential
    gaps are *rescaled* by the instantaneous rate multiplier (a thinned
    inhomogeneous Poisson process), so the base RNG stream is untouched
    — only burst starts draw from the trace RNG."""
    t = 0.0
    burst_until = -1.0
    shaped = np.empty(len(gaps))
    for i, g in enumerate(gaps):
        mult = 1.0
        if cfg.diurnal_amplitude > 0:
            mult *= max(0.05, 1.0 + cfg.diurnal_amplitude *
                        np.sin(2.0 * np.pi * t / cfg.diurnal_period_s))
        if t < burst_until:
            mult *= cfg.burst_factor
        t += g / mult
        shaped[i] = t
        if cfg.burst_prob > 0 and t >= burst_until and \
                trng.uniform() < cfg.burst_prob:
            burst_until = t + cfg.burst_len_s
    return shaped


def _tenant_pool(cfg: WorkloadConfig, mix: dict,
                 trng: np.random.Generator | None):
    """(tenant specs, zipf popularity) for the multi-tenant client pool.
    Each tenant's modality mix interpolates between a text-heavy and a
    video-heavy lean blended with the base mix — distinct but related
    clients, per ServeGen — and carries one shared system prompt."""
    if cfg.tenants <= 0:
        return [], None
    base = np.array([mix["text"], mix["image"], mix["video"]])
    specs = []
    for k in range(cfg.tenants):
        f = k / max(1, cfg.tenants - 1)
        lean = (np.array([0.90, 0.08, 0.02]) * (1 - f)
                + np.array([0.25, 0.25, 0.50]) * f)
        w = 0.5 * base + 0.5 * lean
        w = w / w.sum()
        sys_toks = int(trng.integers(cfg.tenant_sys_tokens_min,
                                     cfg.tenant_sys_tokens_max + 1))
        specs.append((f"tenant{k}", w, f"t{cfg.seed}-{k}", sys_toks))
    ranks = np.arange(1, cfg.tenants + 1, dtype=float)
    pop = ranks ** -cfg.tenant_zipf_a
    return specs, pop / pop.sum()


def generate(cfg: WorkloadConfig) -> list[Request]:
    rng = np.random.default_rng(cfg.seed)
    mix = MIXES[cfg.mix]
    modalities = rng.choice(
        ["text", "image", "video"], size=cfg.num_requests,
        p=[mix["text"], mix["image"], mix["video"]])
    gaps = rng.exponential(1.0 / cfg.rate, size=cfg.num_requests)
    arrivals = np.cumsum(gaps)

    # trace-shaped extras (ISSUE 8) live on a separate RNG stream: with
    # every knob at its default this block draws nothing and the base
    # stream stays byte-identical to the historical generator
    trace_on = (cfg.heavy_tail_prob > 0 or cfg.diurnal_amplitude > 0
                or cfg.burst_prob > 0 or cfg.tenants > 0)
    trng = np.random.default_rng(cfg.seed + 0x7ACE) if trace_on else None
    if cfg.diurnal_amplitude > 0 or cfg.burst_prob > 0:
        arrivals = _shape_arrivals(cfg, gaps, trng)
    tenant_specs, tenant_pop = _tenant_pool(cfg, mix, trng)

    reqs = []
    # previously-generated mm contents per modality: (hash, units) pools
    # that duplicate_prob draws from (the same image re-asked with a new
    # question shares the hash AND the patch count — content identity)
    pools: dict[str, list[tuple[str, int]]] = {"image": [], "video": []}
    # fixed system-prompt pool (shared_prefix_prob): sizes come from a
    # separate RNG so enabling the knob leaves the main stream's draws
    # for sizes/arrivals untouched
    sys_pool: list[tuple[str, int]] = []
    if cfg.shared_prefix_prob > 0:
        prng = np.random.default_rng(cfg.seed + 0x5F5)
        sys_pool = [
            (f"s{cfg.seed}-{j}",
             int(prng.integers(cfg.shared_prefix_tokens_min,
                               cfg.shared_prefix_tokens_max + 1)))
            for j in range(cfg.shared_prefix_pool)]
    for i, (mod, t) in enumerate(zip(modalities, arrivals)):
        tenant = "default"
        shared_id, shared_toks = None, 0
        if tenant_specs:
            k = int(trng.choice(len(tenant_specs), p=tenant_pop))
            tenant, tmix, sys_id, sys_toks = tenant_specs[k]
            # tenants have distinct modality mixes: redraw from this
            # tenant's lean (the base draw above is discarded)
            mod = str(trng.choice(["text", "image", "video"], p=tmix))
            if trng.uniform() < cfg.tenant_sys_prob:
                shared_id, shared_toks = sys_id, sys_toks
        out_toks = int(np.clip(rng.lognormal(
            cfg.out_tokens_log_mu, cfg.out_tokens_log_sigma), 4, 1024))
        if cfg.heavy_tail_prob > 0 and trng.uniform() < cfg.heavy_tail_prob:
            out_toks = min(cfg.heavy_tail_out_cap,
                           int(32 * (1 + trng.pareto(cfg.heavy_tail_alpha))))
        mm_hash = None
        if mod == "text":
            text = int(np.clip(rng.lognormal(
                cfg.text_tokens_log_mu, cfg.text_tokens_log_sigma), 10, 10000))
            mm = 0
            if cfg.heavy_tail_prob > 0 and \
                    trng.uniform() < cfg.heavy_tail_prob:
                text = min(cfg.heavy_tail_text_cap,
                           int(200 * (1 + trng.pareto(cfg.heavy_tail_alpha))))
            if shared_id is None and sys_pool and \
                    rng.uniform() < cfg.shared_prefix_prob:
                shared_id, shared_toks = \
                    sys_pool[int(rng.integers(len(sys_pool)))]
        else:
            text = int(np.clip(rng.lognormal(3.6, 0.6), 8, 256))
            if cfg.duplicate_prob > 0 and pools[mod] and \
                    rng.uniform() < cfg.duplicate_prob:
                mm_hash, mm = pools[mod][int(rng.integers(len(pools[mod])))]
            else:
                if mod == "image":
                    mm = int(cfg.image_patches *
                             (1 + rng.uniform(-cfg.image_patch_jitter,
                                              cfg.image_patch_jitter)))
                else:  # video
                    frames = int(rng.integers(cfg.video_frames_min,
                                              cfg.video_frames_max + 1))
                    mm = frames * cfg.video_patches_per_frame
                mm_hash = f"{mod}-{i:05d}"
                pools[mod].append((mm_hash, mm))
        if shared_id is not None:
            text += shared_toks   # the system prompt precedes the
            #                       question in the prompt layout
        reqs.append(Request(
            rid=f"r{i:05d}", modality=Modality(mod), arrival=float(t),
            text_tokens=text, mm_units=mm, output_tokens=out_toks,
            prompt_tokens=text + mm, mm_hash=mm_hash,
            shared_prefix_id=shared_id, shared_prefix_tokens=shared_toks,
            tenant=tenant))
    return reqs


def long_context_video(cap_tokens: int, *, num_requests: int = 64,
                       rate: float = 1.0, seed: int = 0) -> WorkloadConfig:
    """Long-context video preset: an LCV-mix workload whose video rocks
    carry prompts near ``cap_tokens`` (the serving context cap).

    Frame counts are sized so a max-frame video plus its text lands just
    under the cap (~90%, leaving decode headroom) and the minimum stays
    above half of it — every video is a genuine rock, not a pebble. The
    executor context-sweep benchmark draws its long-context rung from
    this preset (benchmarks/real_executor.py), so the committed numbers
    exercise the regime the ROADMAP's video north-star cares about.
    """
    patches = 196
    frames_max = max(1, (cap_tokens * 9 // 10) // patches)
    frames_min = max(1, frames_max // 2)
    return WorkloadConfig(
        mix="LCV", rate=rate, num_requests=num_requests, seed=seed,
        video_frames_min=frames_min, video_frames_max=frames_max,
        video_patches_per_frame=patches)


def profiling_workload(seed: int = 1234, n_per_modality: int = 120) -> list[Request]:
    """Isolated-run workload for the Workload Profiler: sweeps input sizes."""
    rng = np.random.default_rng(seed)
    reqs = []
    i = 0
    for text in np.unique(np.geomspace(10, 10000, n_per_modality).astype(int)):
        reqs.append(Request(rid=f"pT{i}", modality=Modality.TEXT, arrival=0.0,
                            text_tokens=int(text), prompt_tokens=int(text)))
        i += 1
    for _ in range(n_per_modality):
        text = int(rng.integers(8, 256))
        mm = int(576 * (1 + rng.uniform(-0.15, 0.15)))
        reqs.append(Request(rid=f"pI{i}", modality=Modality.IMAGE, arrival=0.0,
                            text_tokens=text, mm_units=mm,
                            prompt_tokens=text + mm))
        i += 1
    for frames in np.unique(np.geomspace(8, 96, n_per_modality).astype(int)):
        text = int(rng.integers(8, 256))
        mm = int(frames) * 196
        reqs.append(Request(rid=f"pV{i}", modality=Modality.VIDEO, arrival=0.0,
                            text_tokens=text, mm_units=mm,
                            prompt_tokens=text + mm))
        i += 1
    return reqs
