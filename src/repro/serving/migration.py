"""Page-chain migration between replicas (ISSUE 9 tentpole).

When the fleet drains a replica (rolling restart, scale-down) or rebalances
after an elastic repartition, in-flight requests move to another replica.
Without migration every move pays a full re-prefill on the target — for a
truck (video) request that is seconds of recomputation the source already
did. This module transfers the request's *prefilled KV page chain* instead,
so the target re-prefills only the residual.

The protocol leans on the prefix-cache substrate (cache/allocator.py):

  * **manifest = trie path.** Each prefilled full page is described by its
    page-run tuple — the same ``(content_id, offset, length)`` key the
    prefix trie hashes — so the target can install the chain with
    ``BlockAllocator.import_chain`` and the migrated request re-claims it
    through the ordinary ``match_prefix``/``claim_prefix`` admission flow.
    Dedup is free: chain positions the target already caches are skipped.
  * **per-page checksums.** Every ``PageRecord`` carries a CRC over its
    identity (chain index + runs) and its KV payload bytes; the receiver
    recomputes and rejects mismatches, so a corrupted chunk can never be
    installed as valid KV.
  * **bounded chunks, timeout, retry-with-backoff.** The chain ships in
    chunks of ``chunk_pages`` records. A chunk that times out or fails
    verification is retried with exponential backoff up to ``max_retries``;
    exhaustion stops the transfer at the last verified chunk.
  * **graceful degradation.** Any truncation — fault exhaustion, source
    dying mid-transfer, target capacity — yields a shorter verified prefix;
    the request simply re-prefills a longer residual on the target.
    Correctness is never at stake, only latency. Only a *target* death
    aborts the import entirely (the fleet re-dispatches elsewhere).

Timing is simulated on the stepped co-sim clock: the transfer spans
``[start, finish_time]`` and the migrated request's ``ready_floor`` holds
it un-schedulable on the target until the chain has "landed". Faults come
from ``FaultPlan.migration_fault`` — deterministic per (seed, rid, chunk),
so every chaos schedule replays bit-identically. In real-executor mode the
payload bytes genuinely move (``export_page_payload`` on the source,
``import_page_payload`` on the target); KV values are bf16-rounded on
write, so the bytes round-trip exactly and a migrated request decodes the
same tokens it would have decoded without the move.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field

from ..cache.allocator import _shareable, iter_page_runs
from .request import Request


@dataclass(frozen=True)
class MigrationConfig:
    """Transfer-protocol knobs (times in simulated seconds)."""
    chunk_pages: int = 8              # records per bounded chunk
    bandwidth_pages_per_s: float = 2000.0   # sustained inter-replica rate
    chunk_latency_s: float = 0.002    # fixed per-chunk RPC overhead
    chunk_timeout_s: float = 0.25     # deadline per chunk attempt
    max_retries: int = 3              # attempts per chunk past the first
    retry_backoff_s: float = 0.05     # base backoff, doubles per retry


@dataclass
class PageRecord:
    """One manifest entry: a prefilled page's identity + (optionally) its
    KV bytes. ``runs`` is the page's trie key; ``payload`` is None in
    sim-executor mode, where KV content is implicit."""
    index: int                 # position in the chain (0-based)
    runs: tuple                # page-run tuple (the trie/transfer key)
    tokens: int                # token count (== page_size for full pages)
    payload: bytes | None = None
    checksum: int = 0

    def seal(self) -> "PageRecord":
        self.checksum = record_checksum(self)
        return self


def record_checksum(rec: PageRecord) -> int:
    """CRC over the record's identity and payload — what the receiver
    recomputes on arrival."""
    c = zlib.crc32(repr((rec.index, rec.runs, rec.tokens)).encode())
    if rec.payload is not None:
        c = zlib.crc32(rec.payload, c)
    return c & 0xFFFFFFFF


@dataclass
class MigrationResult:
    """Outcome of one attempted page-chain transfer."""
    status: str                # migrated | fallback | aborted_source_dead
    #                            | aborted_target_dead
    delivered: list = field(default_factory=list)  # verified PageRecords
    finish_time: float = 0.0   # sim time the last verified chunk landed
    retries: int = 0           # chunk re-attempts (timeouts + corruptions)
    chunks_sent: int = 0
    pages_imported: int = 0    # fresh pages installed on the target
    pages_deduped: int = 0     # chain positions the target already cached


def build_manifest(engine, req: Request) -> list[PageRecord]:
    """Snapshot ``req``'s transferable chain on its source engine — MUST
    run before ``export_request`` frees the source pages.

    Transferable = fully-prefilled *full* pages whose leading run is
    shareable, stopping after the first page that mixes in private
    content (the same truncation ``import_chain`` applies — a private-led
    page can never be matched on the target). Block tables are
    positional, so chain position ``i`` is ``pages_of(rid)[i]``.
    """
    alloc = engine.allocator
    owned = alloc.pages_of(req.rid)
    usable = min(req.prefilled, req.prompt_tokens)
    exec_ = engine.executor
    can_payload = hasattr(exec_, "export_page_payload") and \
        getattr(exec_, "supports_prefix_cache", False)
    manifest: list[PageRecord] = []
    for i, (runs, ptoks) in enumerate(
            iter_page_runs(req.content_chunks(), alloc.page_size)):
        if ptoks < alloc.page_size or i >= len(owned):
            break                       # partial/unallocated tail
        if (i + 1) * alloc.page_size > usable:
            break                       # page not fully prefilled yet
        if not _shareable(runs[0][0]):
            break                       # private-led: unmatchable
        payload = None
        if can_payload:
            payload = exec_.export_page_payload([owned[i]])[0]
        manifest.append(
            PageRecord(i, runs, ptoks, payload).seal())
        if any(not _shareable(cid) for cid, _o, _l in runs):
            break   # mixed boundary page: donor only, chain ends here
    return manifest


def _corrupted(rec: PageRecord) -> PageRecord:
    """What a corrupt chunk delivers on the wire: same record with one
    payload byte flipped (or, with no payload, a tampered checksum) —
    verification then genuinely fails, it is not merely declared to."""
    if rec.payload:
        bad = bytearray(rec.payload)
        bad[0] ^= 0xFF
        return PageRecord(rec.index, rec.runs, rec.tokens, bytes(bad),
                          rec.checksum)
    return PageRecord(rec.index, rec.runs, rec.tokens, None,
                      rec.checksum ^ 0x1)


def simulate_transfer(manifest: list[PageRecord], rid: str, start: float,
                      cfg: MigrationConfig, plan=None,
                      src_kill: float | None = None,
                      dst_kill: float | None = None) -> MigrationResult:
    """Run the chunked transfer protocol on the simulated clock.

    Returns the verified delivered prefix and when it landed. Chunks are
    sent in order; a chunk is retried (backoff doubling) while
    ``plan.migration_fault`` faults it, and the transfer degrades to the
    verified prefix when retries exhaust (``fallback``). A source death
    (``src_kill``) cuts the stream — already-verified chunks remain
    importable; a target death (``dst_kill``) aborts the import wholesale.
    """
    res = MigrationResult(status="migrated", finish_time=start)
    if not manifest:
        res.status = "fallback"
        return res
    t = start
    chunks = [manifest[i:i + cfg.chunk_pages]
              for i in range(0, len(manifest), cfg.chunk_pages)]
    for ci, chunk in enumerate(chunks):
        xfer = cfg.chunk_latency_s + len(chunk) / cfg.bandwidth_pages_per_s
        attempt = 0
        while True:
            fault = (plan.migration_fault(rid, ci, attempt)
                     if plan is not None else None)
            dur = cfg.chunk_timeout_s if fault == "timeout" else xfer
            # a replica dying mid-attempt means the attempt never
            # completes: cut the stream at the last verified chunk
            if dst_kill is not None and t + dur > dst_kill:
                res.status = "aborted_target_dead"
                return res
            if src_kill is not None and t + dur > src_kill:
                res.status = "aborted_source_dead"
                return res
            if fault == "timeout":
                t += dur                      # the chunk never arrives
                ok = False
            else:
                t += dur
                wire = [(_corrupted(r) if fault == "corrupt" else r)
                        for r in chunk]
                ok = all(record_checksum(r) == r.checksum for r in wire)
            res.chunks_sent += 1
            if ok:
                res.delivered.extend(chunk)
                res.finish_time = t
                break
            res.retries += 1
            if attempt >= cfg.max_retries:
                res.status = "fallback"       # keep the verified prefix
                return res
            t += cfg.retry_backoff_s * (2 ** attempt)
            attempt += 1
    return res


def warm_import(src_engine, dst_engine, start: float,
                cfg: MigrationConfig, plan=None,
                max_pages: int = 256) -> MigrationResult:
    """Warm a restarted replica's prefix trie from a healthy peer
    (ISSUE 10): ship the peer's hottest cached chains over the same
    chunked/verified page-chain protocol a migration uses and install
    them zero-ref/evictable on the target (``import_chain`` dedupes, so
    re-warming is idempotent). Purely a latency optimization — any
    truncation (faults, capacity) just means a colder cache on rejoin;
    the pages are owned by nobody, so nothing can leak. Returns one
    aggregate result; ``finish_time`` is when the last verified chunk
    landed (the fleet gates rejoin on it)."""
    total = MigrationResult(status="migrated", finish_time=start)
    chains = src_engine.allocator.export_hot_chains(max_pages)
    exec_ = src_engine.executor
    can_payload = hasattr(exec_, "export_page_payload") and \
        getattr(exec_, "supports_prefix_cache", False)
    t = start
    for ci, chain in enumerate(chains):
        manifest = []
        for i, (runs, ptoks, page) in enumerate(chain):
            payload = (exec_.export_page_payload([page])[0]
                       if can_payload else None)
            manifest.append(PageRecord(i, runs, ptoks, payload).seal())
        res = simulate_transfer(manifest, f"warm-{ci}", t, cfg, plan)
        total.chunks_sent += res.chunks_sent
        total.retries += res.retries
        t = max(t, res.finish_time)
        if res.delivered:
            by_index = {r.index: r for r in res.delivered}
            installed = dst_engine.allocator.import_chain(
                [(r.runs, r.tokens) for r in res.delivered])
            fresh_pages, fresh_payloads = [], []
            for idx, page, fresh in installed:
                if fresh:
                    total.pages_imported += 1
                    rec = by_index[idx]
                    if rec.payload is not None:
                        fresh_pages.append(page)
                        fresh_payloads.append(rec.payload)
                else:
                    total.pages_deduped += 1
            if fresh_pages and hasattr(dst_engine.executor,
                                       "import_page_payload"):
                dst_engine.executor.import_page_payload(fresh_pages,
                                                        fresh_payloads)
            if getattr(dst_engine, "journal", None) is not None:
                dst_engine.journal.record(t, "migrate_in", f"warm-{ci}",
                                          len(installed))
        if res.status != "migrated":
            total.status = "fallback"
    total.finish_time = t
    return total


def apply_to_target(engine, req: Request, res: MigrationResult) -> None:
    """Install the delivered verified prefix on the target engine and arm
    the request's transfer hold. Safe for any delivered prefix (including
    empty — a pure fallback just re-prefills everything); never called
    for ``aborted_target_dead``.
    """
    if res.status == "aborted_target_dead":
        return
    if res.delivered:
        by_index = {r.index: r for r in res.delivered}
        installed = engine.allocator.import_chain(
            [(r.runs, r.tokens) for r in res.delivered])
        fresh_pages, fresh_payloads = [], []
        for idx, page, fresh in installed:
            if fresh:
                res.pages_imported += 1
                rec = by_index[idx]
                if rec.payload is not None:
                    fresh_pages.append(page)
                    fresh_payloads.append(rec.payload)
            else:
                res.pages_deduped += 1
        if fresh_pages and hasattr(engine.executor, "import_page_payload"):
            engine.executor.import_page_payload(fresh_pages, fresh_payloads)
        if getattr(engine, "journal", None) is not None:
            # informational (replay no-op): the chain enters the target's
            # *cache*, not the rid's ownership — the request re-claims it
            # through ordinary admission, which journals the acquire
            engine.journal.record(res.finish_time, "migrate_in", req.rid,
                                  len(res.delivered))
        # only a transfer that landed something holds the request; a pure
        # fallback is a plain re-dispatch (nothing to wait for)
        req.ready_floor = res.finish_time


def migrate(src_engine, dst_engine, req: Request, start: float,
            cfg: MigrationConfig, plan=None,
            src_kill: float | None = None,
            dst_kill: float | None = None) -> MigrationResult:
    """Full migration of one non-terminal request: snapshot the manifest,
    release every source-side resource (exactly once), run the transfer,
    install the verified prefix on the target, and reset the request for
    re-dispatch with its transfer hold armed.

    The caller routes the request to ``dst_engine``'s pending list
    afterwards — except on ``aborted_target_dead``, where nothing was
    installed and the request must go to a *different* replica (its
    ``ready_floor`` stays 0: no transfer landed anywhere).
    """
    manifest = build_manifest(src_engine, req)
    src_engine.export_request(req)
    req.reset_for_redispatch()
    res = simulate_transfer(manifest, req.rid, start, cfg, plan,
                            src_kill, dst_kill)
    if res.status != "aborted_target_dead":
        apply_to_target(dst_engine, req, res)
        if res.delivered:
            req.migrations += 1
    return res
