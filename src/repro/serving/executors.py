"""Execution backends for the serving engine.

SimExecutor — iteration-cost model calibrated to the paper's own
characterization (Figs. 2 and 6): per-token prefill cost from model FLOPs /
device throughput, quadratic attention term, per-iteration decode cost,
modality preprocess/encode stage costs. Used for workload-scale scheduler
experiments (the scheduler sees the identical engine API either way).

ModelExecutor — runs the real JAX model (reduced config) with the dense
slot cache; proves the engine end-to-end on CPU and backs the examples.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core.profiler import ProfileRecord

from .request import Modality, Request


@dataclass
class CostModel:
    """Analytic A100-class iteration-time model for one MLLM.

    prefill: t = c_base + c_tok * T + c_attn * T^2   (T = chunk tokens;
    the quadratic term uses chunk x context for chunked prefill)
    decode:  t = d_base + d_tok * B (+ attention over context)
    encode:  per-modality preprocess/encode from unit counts.
    """
    name: str = "llava-7b"
    n_params: float = 7e9
    peak_flops: float = 312e12 * 0.60   # A100 bf16 with realistic MFU
    c_base: float = 0.004
    d_base: float = 0.008
    kv_bytes_per_token: float = 2 * 32 * 1024 * 2  # 2(KV) * L*d_kv * bf16
    hbm_bw: float = 1.5e12 * 0.8
    # vision stage (calibrated to paper Fig. 6: image TTFT < 1 s, video 1-10 s)
    img_preproc_s: float = 0.030
    img_encode_per_patch: float = 5e-5
    vid_preproc_per_frame: float = 0.004
    vid_encode_per_patch: float = 2.5e-5
    # encode/LLM stage overlap (RServe-style pipelining): fraction of the
    # shorter stage hidden behind the longer when both run in the same
    # iteration (< 1.0: launch gaps, shared SMs/HBM contention)
    overlap_efficiency: float = 0.88

    def prefill_time(self, chunk_tokens: int, ctx_before: int) -> float:
        flops = 2.0 * self.n_params * chunk_tokens
        # attention reads the context KV once per chunk
        attn = (ctx_before + chunk_tokens / 2) * chunk_tokens * 4e-9 / 50
        return flops / self.peak_flops + attn

    def decode_time(self, batch: int, ctx_tokens_total: int) -> float:
        # weights + KV reads are bandwidth-bound at decode
        weight_read = 2.0 * self.n_params / self.hbm_bw
        kv_read = ctx_tokens_total * self.kv_bytes_per_token / self.hbm_bw
        return weight_read + kv_read + 2.0 * self.n_params * batch / self.peak_flops

    def preprocess_time(self, req: Request) -> float:
        if req.modality == Modality.IMAGE:
            return self.img_preproc_s
        if req.modality == Modality.VIDEO:
            frames = req.mm_units / 196
            return self.vid_preproc_per_frame * frames
        return 0.0

    def encode_chunk_time(self, req: Request, units: int) -> float:
        """Encoder time for ``units`` patches of this request's modality.
        Linear in units, so chunked encode conserves total work exactly."""
        if req.modality == Modality.IMAGE:
            return self.img_encode_per_patch * units
        if req.modality == Modality.VIDEO:
            return self.vid_encode_per_patch * units
        return 0.0

    def encode_time(self, req: Request) -> float:
        return self.encode_chunk_time(req, req.mm_units)


# Paper-table model presets (Table 1) + assigned archs. Coefficients scale
# with LLM-backend parameter count; vision stages with encoder size.
MODEL_PRESETS = {
    "llava-500m": dict(n_params=5e8, d_base=0.004),
    "llava-7b": dict(n_params=7e9),
    "gemma-4b": dict(n_params=4e9),
    "gemma-12b": dict(n_params=12e9),
    "qwen-3b": dict(n_params=3e9),
    "qwen-7b": dict(n_params=7e9, vid_encode_per_patch=1.2e-4),
    "pixtral-12b": dict(n_params=12e9, img_encode_per_patch=5e-5),
}


def make_cost_model(name: str) -> CostModel:
    return CostModel(name=name, **MODEL_PRESETS[name])


def cost_model_for_arch(cfg) -> CostModel:
    """Cost model derived from an assigned architecture's dimensions."""
    from repro.models.params import param_count
    from repro.models.transformer import model_decls
    n = param_count(model_decls(cfg))
    kv_bytes = 2 * cfg.num_layers * cfg.num_kv_heads * cfg.hd * 2
    return CostModel(name=cfg.name, n_params=float(n),
                     kv_bytes_per_token=float(max(kv_bytes, 1)))


class SimExecutor:
    """Calibrated discrete-event executor.

    ``overlap=True`` pipelines the vision-encode stage with LLM
    prefill/decode inside an iteration (max- rather than sum-composition
    of the stage times, up to ``CostModel.overlap_efficiency``); with
    ``overlap=False`` the stages serialize, which is the ablation baseline
    for benchmarks/encode_overlap.py. Stage-second counters accumulate
    across iterations so tests can assert work conservation.
    """

    def __init__(self, cost_model: CostModel, decode_block: int = 1,
                 overlap: bool = True):
        self.cm = cost_model
        self.overlap = overlap
        self.llm_seconds = 0.0       # prefill + decode stage time
        self.encode_seconds = 0.0    # vision-encode stage time
        self.overlap_saved_seconds = 0.0
        self.busy_seconds = 0.0      # sum of returned iteration durations

    def preprocess_delay(self, req: Request) -> float:
        return self.cm.preprocess_time(req)

    # -- profiler interface -------------------------------------------------
    def isolated_run(self, req: Request) -> ProfileRecord:
        pre = self.cm.preprocess_time(req)
        enc = self.cm.encode_time(req)
        prefill = self.cm.prefill_time(req.prompt_tokens, 0)
        return ProfileRecord(
            modality=req.modality.value, text_tokens=req.text_tokens,
            mm_units=req.mm_units, prompt_tokens=req.prompt_tokens,
            preprocess_time=pre, encode_time=enc, prefill_time=prefill)

    def isolated_e2e(self, req: Request) -> float:
        """Isolated end-to-end latency; called once per request at ingest
        (SLO assignment), so the decode sum over
        ``decode_time(1, prompt + i) for i < output_tokens`` is evaluated in
        closed form: the cost model is affine in context, so the sum is an
        arithmetic series — O(1) instead of an O(output_tokens) loop."""
        rec = self.isolated_run(req)
        n = req.output_tokens
        base = self.cm.decode_time(1, 0)          # weights + batch FLOPs term
        kv_coef = self.cm.kv_bytes_per_token / self.cm.hbm_bw
        ctx_sum = n * req.prompt_tokens + n * (n - 1) // 2
        return rec.ttft + n * base + kv_coef * ctx_sum

    # -- engine interface ----------------------------------------------------
    def run_iteration(self, prefill_work, decode_reqs, encode_work) -> float:
        """Returns the iteration duration in (simulated) seconds.

        prefill_work: list[(Request, chunk_tokens)]; decode_reqs: requests
        each generating one token; encode_work: list[(Request,
        chunk_units)] vision-encode chunks running this iteration.
        Preprocess runs async on CPU (vLLM-style), so only encode hits the
        accelerator; with overlap enabled the encode stream hides behind
        (or hides) the LLM stream up to the overlap efficiency.
        """
        t_enc = 0.0
        for req, units in encode_work:
            t_enc += self.cm.encode_chunk_time(req, units)
        t_llm = 0.0
        if prefill_work:
            t_llm += self.cm.c_base
            for r, c in prefill_work:
                t_llm += self.cm.prefill_time(c, r.prefilled)
        if decode_reqs:
            ctx = sum(r.prompt_tokens + r.decoded for r in decode_reqs)
            t_llm += self.cm.decode_time(len(decode_reqs), ctx)
        saved = 0.0
        if self.overlap and t_enc > 0.0 and t_llm > 0.0:
            saved = self.cm.overlap_efficiency * min(t_llm, t_enc)
        dur = max(t_llm + t_enc - saved, 1e-3)
        self.llm_seconds += t_llm
        self.encode_seconds += t_enc
        self.overlap_saved_seconds += saved
        self.busy_seconds += dur
        return dur


class ModelExecutor:
    """Real-JAX backend over a reduced model with a dense slot cache.

    Wall-clock timings on CPU are *measured* (they drive the engine clock in
    real mode); token values are actually computed, proving the engine +
    cache + kernels end-to-end.
    """

    def __init__(self, cfg, max_slots: int = 8, max_len: int = 512, seed=0):
        import jax
        import jax.numpy as jnp

        from repro.models import transformer as T
        from repro.models.params import init_params
        self.jnp = jnp
        self.jax = jax
        self.T = T
        self.cfg = cfg
        self.max_len = max_len
        key = jax.random.PRNGKey(seed)
        self.params = init_params(T.model_decls(cfg), key)
        self.caches = [init_params(T.cache_decls(cfg, 1, max_len), key)
                       for _ in range(max_slots)]
        self.slot_of: dict[str, int] = {}
        self.free_slots = list(range(max_slots))

    def _tokens_for(self, req: Request, start: int, n: int):
        rng = np.random.default_rng(abs(hash(req.rid)) % (2**31))
        toks = rng.integers(1, self.cfg.vocab_size, size=req.prompt_tokens)
        return self.jnp.asarray(toks[start:start + n], self.jnp.int32)[None]

    def acquire_slot(self, req: Request):
        if req.rid not in self.slot_of:
            self.slot_of[req.rid] = self.free_slots.pop()
        return self.slot_of[req.rid]

    def release_slot(self, req: Request):
        slot = self.slot_of.pop(req.rid, None)
        if slot is not None:
            import jax
            self.caches[slot] = jax.tree.map(
                lambda a: a * 0 if a.ndim else a * 0, self.caches[slot])
            self.free_slots.append(slot)

    def isolated_run(self, req: Request) -> ProfileRecord:
        t0 = time.perf_counter()
        slot = self.acquire_slot(req)
        n = min(req.prompt_tokens, self.max_len - 8)
        toks = self._tokens_for(req, 0, n)
        logits, cache, _ = self.T.forward(self.params, self.cfg, toks,
                                          cache=self.caches[slot], q_start=0)
        logits.block_until_ready()
        prefill = time.perf_counter() - t0
        self.caches[slot] = cache
        self.release_slot(req)
        return ProfileRecord(
            modality=req.modality.value, text_tokens=req.text_tokens,
            mm_units=req.mm_units, prompt_tokens=req.prompt_tokens,
            preprocess_time=0.0, encode_time=0.0, prefill_time=prefill)

    def isolated_e2e(self, req: Request) -> float:
        rec = self.isolated_run(req)
        return rec.ttft * (1 + 0.1 * req.output_tokens)

    def encode_chunk(self, req: Request, units: int) -> None:
        """Vision-encoder stage hook. The reduced models ship no real
        encoder, so this stands in with a chunk-sized JAX op — the engine
        clock still pays a *measured* per-chunk cost, and subclasses
        override this to run an actual encoder."""
        n = max(1, min(int(units), 256))
        x = self.jnp.ones((n, 32), self.jnp.float32)
        (x @ x.T).block_until_ready()

    def run_iteration(self, prefill_work, decode_reqs, encode_work) -> float:
        t0 = time.perf_counter()
        jnp = self.jnp
        for req, units in encode_work:
            self.encode_chunk(req, units)
        for req, chunk in prefill_work:
            slot = self.acquire_slot(req)
            n = min(chunk, self.max_len - req.prefilled - 4)
            if n <= 0:
                continue
            toks = self._tokens_for(req, req.prefilled, n)
            _, cache, _ = self.T.forward(
                self.params, self.cfg, toks, cache=self.caches[slot],
                q_start=req.prefilled)
            self.caches[slot] = cache
        for req in decode_reqs:
            slot = self.acquire_slot(req)
            pos = min(req.prompt_tokens + req.decoded, self.max_len - 2)
            tok = jnp.zeros((1, 1), jnp.int32)
            logits, cache, _ = self.T.forward(
                self.params, self.cfg, tok,
                positions=jnp.full((1, 1), pos, jnp.int32),
                cache=self.caches[slot], q_start=pos)
            self.caches[slot] = cache
        return time.perf_counter() - t0
