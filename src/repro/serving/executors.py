"""Execution backends for the serving engine.

SimExecutor — iteration-cost model calibrated to the paper's own
characterization (Figs. 2 and 6): per-token prefill cost from model FLOPs /
device throughput, quadratic attention term, per-iteration decode cost,
modality preprocess/encode stage costs. Used for workload-scale scheduler
experiments (the scheduler sees the identical engine API either way).

ModelExecutor — runs the real JAX model (reduced config). The default
batched mode executes each engine iteration as one jit-compiled packed
prefill step plus one fused decode step over the whole running set, with
per-layer KV in paged stores indexed by the engine allocator's block
tables (DESIGN.md §Batched execution path). ``legacy=True`` keeps the
seed's one-``forward``-per-request dense-slot path as the token-parity
oracle and benchmark baseline (benchmarks/real_executor.py asserts the
two emit bit-identical tokens).
"""
from __future__ import annotations

import time
import zlib
from collections import deque
from dataclasses import dataclass, replace

import numpy as np

from repro.core.profiler import ProfileRecord

from .request import TERMINAL_STATES, Modality, Request


class SlotCapacityError(RuntimeError):
    """Legacy dense-slot executor ran out of request slots (the seed raised
    a bare IndexError from ``free_slots.pop()``). Raise ``max_slots`` or
    lower ``EngineConfig.max_num_seqs``."""


@dataclass
class CostModel:
    """Analytic A100-class iteration-time model for one MLLM.

    prefill: t = c_base + c_tok * T + c_attn * T^2   (T = chunk tokens;
    the quadratic term uses chunk x context for chunked prefill)
    decode:  t = d_base + d_tok * B (+ attention over context)
    encode:  per-modality preprocess/encode from unit counts.
    """
    name: str = "llava-7b"
    n_params: float = 7e9
    peak_flops: float = 312e12 * 0.60   # A100 bf16 with realistic MFU
    c_base: float = 0.004
    d_base: float = 0.008
    kv_bytes_per_token: float = 2 * 32 * 1024 * 2  # 2(KV) * L*d_kv * bf16
    hbm_bw: float = 1.5e12 * 0.8
    # vision stage (calibrated to paper Fig. 6: image TTFT < 1 s, video 1-10 s)
    img_preproc_s: float = 0.030
    img_encode_per_patch: float = 5e-5
    vid_preproc_per_frame: float = 0.004
    vid_encode_per_patch: float = 2.5e-5
    # encode/LLM stage overlap (RServe-style pipelining): fraction of the
    # shorter stage hidden behind the longer when both run in the same
    # iteration (< 1.0: launch gaps, shared SMs/HBM contention)
    overlap_efficiency: float = 0.88

    def prefill_time(self, chunk_tokens: int, ctx_before: int) -> float:
        flops = 2.0 * self.n_params * chunk_tokens
        # attention reads the context KV once per chunk
        attn = (ctx_before + chunk_tokens / 2) * chunk_tokens * 4e-9 / 50
        return flops / self.peak_flops + attn

    def decode_time(self, batch: int, ctx_tokens_total: int) -> float:
        # weights + KV reads are bandwidth-bound at decode
        weight_read = 2.0 * self.n_params / self.hbm_bw
        kv_read = ctx_tokens_total * self.kv_bytes_per_token / self.hbm_bw
        return weight_read + kv_read + 2.0 * self.n_params * batch / self.peak_flops

    def preprocess_time(self, req: Request) -> float:
        if req.modality == Modality.IMAGE:
            return self.img_preproc_s
        if req.modality == Modality.VIDEO:
            frames = req.mm_units / 196
            return self.vid_preproc_per_frame * frames
        return 0.0

    def encode_chunk_time(self, req: Request, units: int) -> float:
        """Encoder time for ``units`` patches of this request's modality.
        Linear in units, so chunked encode conserves total work exactly."""
        if req.modality == Modality.IMAGE:
            return self.img_encode_per_patch * units
        if req.modality == Modality.VIDEO:
            return self.vid_encode_per_patch * units
        return 0.0

    def encode_time(self, req: Request) -> float:
        return self.encode_chunk_time(req, req.mm_units)


# Paper-table model presets (Table 1) + assigned archs. Coefficients scale
# with LLM-backend parameter count; vision stages with encoder size.
MODEL_PRESETS = {
    "llava-500m": dict(n_params=5e8, d_base=0.004),
    "llava-7b": dict(n_params=7e9),
    "gemma-4b": dict(n_params=4e9),
    "gemma-12b": dict(n_params=12e9),
    "qwen-3b": dict(n_params=3e9),
    "qwen-7b": dict(n_params=7e9, vid_encode_per_patch=1.2e-4),
    "pixtral-12b": dict(n_params=12e9, img_encode_per_patch=5e-5),
}


def make_cost_model(name: str) -> CostModel:
    return CostModel(name=name, **MODEL_PRESETS[name])


def cost_model_for_arch(cfg) -> CostModel:
    """Cost model derived from an assigned architecture's dimensions."""
    from repro.models.params import param_count
    from repro.models.transformer import model_decls
    n = param_count(model_decls(cfg))
    kv_bytes = 2 * cfg.num_layers * cfg.num_kv_heads * cfg.hd * 2
    return CostModel(name=cfg.name, n_params=float(n),
                     kv_bytes_per_token=float(max(kv_bytes, 1)))


class SimExecutor:
    """Calibrated discrete-event executor.

    ``overlap=True`` pipelines the vision-encode stage with LLM
    prefill/decode inside an iteration (max- rather than sum-composition
    of the stage times, up to ``CostModel.overlap_efficiency``); with
    ``overlap=False`` the stages serialize, which is the ablation baseline
    for benchmarks/encode_overlap.py. Stage-second counters accumulate
    across iterations so tests can assert work conservation.
    """

    # the sim's "KV" is pure accounting, so shared prefix pages cost
    # nothing to honor: residual chunks simply never reach the cost model
    supports_prefix_cache = True

    def __init__(self, cost_model: CostModel, decode_block: int = 1,
                 overlap: bool = True):
        self.cm = cost_model
        self.overlap = overlap
        self.llm_seconds = 0.0       # prefill + decode stage time
        self.encode_seconds = 0.0    # vision-encode stage time
        self.overlap_saved_seconds = 0.0
        self.busy_seconds = 0.0      # sum of returned iteration durations
        self.prefill_tokens = 0      # prompt tokens actually prefilled

    def preprocess_delay(self, req: Request) -> float:
        return self.cm.preprocess_time(req)

    def fresh(self) -> "SimExecutor":
        """A cold executor of the same configuration — what a restarted
        replica binds (ISSUE 10): same cost model, zeroed counters, no
        per-request state (all of that died with the old process)."""
        return SimExecutor(self.cm, overlap=self.overlap)

    # -- profiler interface -------------------------------------------------
    def isolated_run(self, req: Request) -> ProfileRecord:
        pre = self.cm.preprocess_time(req)
        enc = self.cm.encode_time(req)
        prefill = self.cm.prefill_time(req.prompt_tokens, 0)
        return ProfileRecord(
            modality=req.modality.value, text_tokens=req.text_tokens,
            mm_units=req.mm_units, prompt_tokens=req.prompt_tokens,
            preprocess_time=pre, encode_time=enc, prefill_time=prefill)

    def isolated_e2e(self, req: Request) -> float:
        """Isolated end-to-end latency; called once per request at ingest
        (SLO assignment), so the decode sum over
        ``decode_time(1, prompt + i) for i < output_tokens`` is evaluated in
        closed form: the cost model is affine in context, so the sum is an
        arithmetic series — O(1) instead of an O(output_tokens) loop.

        A cached KV prefix (``req.cached_prefix_tokens``) shrinks the
        prefill term to the residual tokens (attention still reads the
        cached context), so the SLO ranks by the work actually left."""
        rec = self.isolated_run(req)
        ttft = rec.ttft
        cached = min(req.cached_prefix_tokens, max(req.prompt_tokens - 1, 0))
        if cached > 0:
            ttft = (rec.preprocess_time + rec.encode_time +
                    self.cm.prefill_time(req.prompt_tokens - cached, cached))
        n = req.output_tokens
        base = self.cm.decode_time(1, 0)          # weights + batch FLOPs term
        kv_coef = self.cm.kv_bytes_per_token / self.cm.hbm_bw
        ctx_sum = n * req.prompt_tokens + n * (n - 1) // 2
        return ttft + n * base + kv_coef * ctx_sum

    # -- engine interface ----------------------------------------------------
    def run_iteration(self, prefill_work, decode_reqs, encode_work) -> float:
        """Returns the iteration duration in (simulated) seconds.

        prefill_work: list[(Request, chunk_tokens)]; decode_reqs: requests
        each generating one token; encode_work: list[(Request,
        chunk_units)] vision-encode chunks running this iteration.
        Preprocess runs async on CPU (vLLM-style), so only encode hits the
        accelerator; with overlap enabled the encode stream hides behind
        (or hides) the LLM stream up to the overlap efficiency.
        """
        t_enc = 0.0
        for req, units in encode_work:
            t_enc += self.cm.encode_chunk_time(req, units)
        t_llm = 0.0
        if prefill_work:
            t_llm += self.cm.c_base
            for r, c in prefill_work:
                t_llm += self.cm.prefill_time(c, r.prefilled)
                self.prefill_tokens += c
        if decode_reqs:
            ctx = sum(r.prompt_tokens + r.decoded for r in decode_reqs)
            t_llm += self.cm.decode_time(len(decode_reqs), ctx)
        saved = 0.0
        if self.overlap and t_enc > 0.0 and t_llm > 0.0:
            saved = self.cm.overlap_efficiency * min(t_llm, t_enc)
        dur = max(t_llm + t_enc - saved, 1e-3)
        self.llm_seconds += t_llm
        self.encode_seconds += t_enc
        self.overlap_saved_seconds += saved
        self.busy_seconds += dur
        return dur


@dataclass(frozen=True)
class ExecutorConfig:
    """Validated construction surface for ``ModelExecutor``.

    One place for what used to be ``__init__`` kwarg sprawl; Engine,
    Router, benchmarks and tests construct through it (bare-kwargs
    construction was removed after its one-release deprecation window
    and now raises ``TypeError``).

    ``resolved()`` is the single derivation point for the ``num_pages``
    default from slot geometry — the constructor and
    ``launch.serve.build_stack`` previously each re-derived it, so the
    admission path and the paged stores agree by construction now.
    """
    max_slots: int = 8
    max_len: int = 512
    seed: int = 0
    legacy: bool = False
    attn_impl: str = "auto"        # auto | kernel | gather
    page_size: int = 16
    num_pages: int | None = None   # None -> resolved() fills the default
    ragged: bool = True

    def __post_init__(self):
        if self.max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {self.max_slots}")
        if self.max_len < 1:
            raise ValueError(f"max_len must be >= 1, got {self.max_len}")
        if self.page_size < 1:
            raise ValueError(
                f"page_size must be >= 1, got {self.page_size}")
        if self.attn_impl not in ("auto", "kernel", "gather"):
            raise ValueError(
                f"attn_impl must be 'auto', 'kernel' or 'gather', got "
                f"{self.attn_impl!r}")
        if self.num_pages is not None and self.num_pages < 1:
            raise ValueError(
                f"num_pages must be >= 1 (or None), got {self.num_pages}")

    @property
    def default_num_pages(self) -> int:
        """KV capacity implied by the slot geometry: pages covering
        ``max_slots`` full context windows."""
        return max(1, self.max_slots * self.max_len // self.page_size)

    def resolved(self) -> "ExecutorConfig":
        """Fill every derived field; idempotent. An explicit
        ``num_pages`` decouples KV capacity from the slot geometry
        (prefix-cache-heavy configs hold far more resident KV than
        ``max_slots x max_len`` implies)."""
        if self.num_pages is not None:
            return self
        return replace(self, num_pages=self.default_num_pages)


class ModelExecutor:
    """Real-JAX backend over a reduced model.

    Batched mode (default, attention-only archs): per-layer KV lives in
    ``PagedStackStore`` page arrays shared by every request; the engine
    allocator's page lists become real block tables. Each iteration runs
    as at most two jit-compiled calls — a packed ragged prefill over this
    iteration's chunks and one fused decode step over the entire running
    set — with page stores donated so XLA updates them in place. The
    stores ride the transformer's layer scan as *carry* (flat
    layers x pages layout, see cache.paged.PagedStore), so a step never
    copies the page arrays and its cost is independent of KV store
    *capacity* — only live tokens are touched. Batch,
    chunk, AND block-table width are bucketed to powers of two (the table
    rounds the batch's max live page count up, capped at ``max_pages``),
    so attention/scatter traffic scales with live context instead of the
    context cap while jit recompiles stay O(log) per axis (counted in
    ``recompile_keys``, bounded by ``recompile_bound()``).

    ``legacy=True`` (or an arch the paged protocol does not cover —
    SSM/xLSTM/sliding-window/cross-attn) runs the seed's per-request
    dense-slot path: one ``T.forward`` per request per iteration (jitted,
    so benchmarks compare batching rather than eager-dispatch overhead).
    Both paths emit real greedy tokens (argmax, fed back as the next
    decode input) into ``emitted``; batched-vs-legacy parity is asserted
    on the *emitted token streams*. (Ragged geometry means the batched
    attention reduction no longer has the legacy cache's shape, so
    bit-identical floats are no longer structurally guaranteed — kernel
    numerics are instead pinned to the ``ref_paged_*`` oracles by
    tests/test_kernels.py, and token parity is what the engine promises.)

    Wall-clock timings on CPU are *measured* (they drive the engine clock
    in real mode); token values are actually computed, proving the engine
    + cache + kernels end-to-end.
    """

    def __init__(self, cfg, config: ExecutorConfig | None = None, **kwargs):
        import jax
        import jax.numpy as jnp

        from repro.cache import BlockAllocator
        from repro.models import transformer as T
        from repro.models.params import init_params
        if kwargs:
            # the PR 7 one-release deprecation window for bare-kwargs
            # construction is over: fail loudly with the migration path
            raise TypeError(
                "ModelExecutor no longer accepts bare keyword arguments "
                f"({sorted(kwargs)}); construct an ExecutorConfig — "
                "ModelExecutor(cfg, ExecutorConfig("
                + ", ".join(f"{k}=..." for k in sorted(kwargs)) + "))")
        if config is None:
            config = ExecutorConfig()
        config = config.resolved()
        self.config = config
        self.jnp = jnp
        self.jax = jax
        self.T = T
        self.cfg = cfg
        self.max_len = config.max_len
        self.max_slots = config.max_slots
        self.paged_ok = T.paged_supported(cfg)
        self.legacy = config.legacy or not self.paged_ok
        # ragged=False pins the block table at the max_pages cap — the
        # fixed-geometry ablation/baseline for the context-sweep benchmark
        self.ragged = config.ragged
        if config.attn_impl == "auto":
            # Pallas kernel natively on TPU; pure-JAX gather+mha path on
            # CPU (the interpret-mode kernel replays the grid in Python —
            # fine for tests, not for the serving hot loop)
            self.attn_impl = ("kernel" if jax.default_backend() == "tpu"
                              else "gather")
        else:
            self.attn_impl = config.attn_impl
        key = jax.random.PRNGKey(config.seed)
        self.params = init_params(T.model_decls(cfg), key)
        # dense per-request slot caches: only the legacy path keeps them
        # (the batched path retires the slot store for attention KV)
        self.caches = ([init_params(T.cache_decls(cfg, 1, self.max_len), key)
                        for _ in range(self.max_slots)]
                       if self.legacy else None)
        self.slot_of: dict[str, int] = {}
        self.free_slots = list(range(self.max_slots))
        # page accounting: replaced by the engine's allocator via
        # bind_allocator; standalone use gets a private one sized by the
        # resolved config (launch plumbs EngineConfig.kv_pages through
        # ExecutorConfig.num_pages so the paged stores match the
        # engine's capacity from the start).
        self.allocator = BlockAllocator(num_pages=config.num_pages,
                                        page_size=config.page_size)
        self._stores = None           # lazy: [{bname: PagedStackStore}]
        self._ctx: dict[str, int] = {}        # KV tokens written per rid
        self._isolated_ttft: dict[str, float] = {}  # measured profile
        #   prefill per rid: repricing an SLO at admission (prefix claim
        #   shifted) must not re-run a profile prefill — the pool may be
        #   full at that point
        self.emitted: dict[str, list[int]] = {}
        self._finished_rids = deque()
        self._prompt_cache: dict[str, np.ndarray] = {}
        self.recompile_keys: set[tuple] = set()
        # one jitted step serves both phases: decode is a 1-token prefill
        # (new_lens 1 -> last_pos 0), so signatures differ only by shape
        self._prefill_jit = jax.jit(self._prefill_step, donate_argnums=(1,))
        # legacy per-request step, jitted: same seed semantics (one call
        # per request, dense slot cache) minus the eager-dispatch tax, so
        # the batched-vs-legacy benchmark measures *batching*, not jit.
        # One signature per distinct chunk length (decode is always (1,1)).
        self._legacy_jit = jax.jit(
            lambda params, tokens, positions, cache, q_start:
            self.T.forward(params, self.cfg, tokens, positions=positions,
                           cache=cache, q_start=q_start))
        # prefix-cache COW: copy one page donor->private across every
        # layer stack in one fused call; src/dst are traced, so a single
        # jit signature serves every copy
        from repro.cache.paged import PagedStackStore
        self._cow_jit = jax.jit(
            lambda stores, src, dst: jax.tree.map(
                lambda s: s.copy_page(src, dst), stores,
                is_leaf=lambda x: isinstance(x, PagedStackStore)),
            donate_argnums=(0,))

    # -- plumbing -----------------------------------------------------------
    def bind_allocator(self, allocator) -> None:
        """Adopt the engine's BlockAllocator: its page ids index the paged
        stores directly (id P — one past the allocator's last — is the
        reserved trash page for ragged-batch padding writes)."""
        if self._stores is not None and (
                allocator.num_pages != self.allocator.num_pages
                or allocator.page_size != self.allocator.page_size):
            self._stores = None   # re-created lazily at the new geometry
        self.allocator = allocator

    @property
    def capacity_pages(self) -> int:
        return self.allocator.num_pages

    def _make_stores(self):
        from repro.cache.paged import PagedStackStore
        cfg = self.cfg
        ppl = self.allocator.num_pages + 1    # +1: per-layer trash page
        page = self.allocator.page_size
        bytes_total = 0
        stores = []
        for period, reps in cfg.stages():
            stage = {}
            for bi, _bt in enumerate(period):
                s = PagedStackStore.build(
                    reps, ppl, page, cfg.num_kv_heads, cfg.hd)
                bytes_total += (s.k_pages.size + s.v_pages.size) * \
                    s.k_pages.dtype.itemsize
                stage[f"b{bi}"] = s
            stores.append(stage)
        if bytes_total > 8 << 30:
            raise ValueError(
                f"paged stores would need {bytes_total / 2**30:.1f} GiB "
                f"({ppl} pages/layer x {page}); size EngineConfig.kv_pages "
                "to the executor (serve.build_stack does this for real "
                "mode)")
        return stores

    @property
    def supports_prefix_cache(self) -> bool:
        """Only the batched paged path shares KV between requests; the
        legacy dense-slot oracle keeps per-request caches and opts out
        (the engine then never claims or publishes)."""
        return not self.legacy

    @property
    def prefix_token_limit(self) -> int:
        """Cap on claimable prefix tokens: a claimed row must still start
        inside the context window so its residual chunk can run."""
        return max(0, self.max_len - 8)

    def on_prefix_claim(self, req: Request, tokens: int,
                        cow_src: int | None = None,
                        cow_dst: int | None = None) -> None:
        """Engine hook at admission: the claimed prefix's KV already sits
        in shared pages (rows 0.. of this request's block table), so
        writes and rope start at ``tokens``; the partially-shared
        boundary page is copied donor->private in one fused jit call."""
        self._ctx[req.rid] = int(tokens)
        self.emitted.pop(req.rid, None)   # recompute re-claims cleanly
        if cow_src is None or cow_dst is None:
            return
        if self._stores is None:
            self._stores = self._make_stores()
        self._stores = self._cow_jit(self._stores,
                                     self.jnp.int32(cow_src),
                                     self.jnp.int32(cow_dst))

    # -- page-chain migration payloads (ISSUE 9) -----------------------------
    def evict_request(self, rid: str) -> None:
        """Drop every per-rid memo (prompt stream, profile, emitted) for a
        request exported off this replica — it will never run here again,
        so the non-terminal retention in ``release_slot`` does not apply."""
        self._prompt_cache.pop(rid, None)
        self._isolated_ttft.pop(rid, None)
        self.emitted.pop(rid, None)
        self._ctx.pop(rid, None)

    def export_page_payload(self, pages: list[int]) -> list[bytes]:
        """Serialize the KV bytes of allocator ``pages`` — one ``bytes``
        blob per page, concatenating every stage/block store's
        ``export_page`` rows in declaration order. The blob is the wire
        payload the migration protocol checksums, chunks, and (on the
        target) hands to ``import_page_payload`` at the target's own page
        ids; both replicas share the model config, so the layout is
        positional. Values are bf16-rounded on write (cache.paged), so
        payload round-trips are bit-exact and migrated prefixes decode
        the same tokens the source would have."""
        if self._stores is None:
            self._stores = self._make_stores()
        out = []
        for p in pages:
            parts = []
            for stage in self._stores:
                for s in stage.values():
                    k, v = s.export_page(p)
                    parts.append(k.tobytes())
                    parts.append(v.tobytes())
            out.append(b"".join(parts))
        return out

    def import_page_payload(self, pages: list[int],
                            payloads: list[bytes]) -> None:
        """Write transferred page blobs into this replica's stores at the
        target-side page ids ``pages`` (``export_page_payload``'s inverse)."""
        import numpy as np
        if self._stores is None:
            self._stores = self._make_stores()
        for p, blob in zip(pages, payloads):
            off = 0
            stores = []
            for stage in self._stores:
                new_stage = {}
                for name, s in stage.items():
                    shape = (s.layers, s.page_size) + s.k_pages.shape[-2:]
                    count = int(np.prod(shape))
                    dt = np.dtype(s.k_pages.dtype)
                    k = np.frombuffer(blob, dt, count,
                                      off).reshape(shape)
                    off += count * dt.itemsize
                    v = np.frombuffer(blob, dt, count,
                                      off).reshape(shape)
                    off += count * dt.itemsize
                    new_stage[name] = s.import_page(p, k, v)
                stores.append(new_stage)
            self._stores = stores

    @property
    def max_pages(self) -> int:
        """Block-table width *cap*: pages covering the per-request context
        window. Per-call tables are length-bucketed below this (see
        ``_bucket_pages``); ``ragged=False`` pins every call here, which
        reproduces the old fixed geometry (and the legacy dense cache's
        attention shapes) as the ablation baseline."""
        return -(-self.max_len // self.allocator.page_size)

    def _bucket_pages(self, need: int) -> int:
        """Block-table width for a call whose widest row holds ``need``
        live pages: round up to a power of two (so jit signatures stay
        O(log) along the context axis), clamped to the ``max_pages`` cap.
        Attention and scatter traffic then scale with the batch's live
        context instead of charging every call the context-cap price."""
        if not self.ragged:
            return self.max_pages
        return min(self._bucket(max(need, 1)), self.max_pages)

    @staticmethod
    def _n_buckets(n: int) -> int:
        """Distinct power-of-two buckets covering 1..n — the O(log n)
        factor each bucketed signature axis contributes."""
        return (max(n, 1) - 1).bit_length() + 1

    def recompile_bound(self) -> int:
        """Ceiling on ``len(recompile_keys)``: each jit-signature axis is
        bucketed, so distinct signatures are bounded by the product of
        O(log) per-axis bucket counts — O(log B · log C · log P) for
        prefill plus O(log B · log P) for decode. Benchmarks and tests
        assert the observed key set stays under this."""
        b_seen = max((k[1] for k in self.recompile_keys), default=1)
        nb = self._n_buckets(b_seen)
        nc = self._n_buckets(self.max_len)
        npg = self._n_buckets(self.max_pages)
        return nb * nc * npg + nb * npg

    # -- deterministic token streams / emission -----------------------------
    def _prompt_tokens(self, req: Request) -> np.ndarray:
        toks = self._prompt_cache.get(req.rid)
        if toks is None:
            chunks = req.content_chunks()
            if len(chunks) == 1 and chunks[0][0] == f"txt!{req.rid}":
                # fully-private prompt: the historical rid-seeded stream
                # (stable digest: abs(hash(rid)) varied across processes
                # under PYTHONHASHSEED, so real-mode runs did not
                # reproduce)
                seed = zlib.crc32(req.rid.encode()) & 0x7FFFFFFF
                toks = np.random.default_rng(seed).integers(
                    1, self.cfg.vocab_size, size=req.prompt_tokens,
                    dtype=np.int64)
            else:
                # per-segment streams seeded by *content id*: requests
                # carrying the same system prompt or mm payload see
                # identical tokens there, so a shared prefix page's KV
                # really is interchangeable between them
                toks = np.concatenate([
                    np.random.default_rng(
                        zlib.crc32(cid.encode()) & 0x7FFFFFFF).integers(
                        1, self.cfg.vocab_size, size=n, dtype=np.int64)
                    for cid, n in chunks]) if chunks else \
                    np.zeros(0, np.int64)
            self._prompt_cache[req.rid] = toks
        return toks

    def _tokens_for(self, req: Request, start: int, n: int):
        toks = self._prompt_tokens(req)[start:start + n]
        return self.jnp.asarray(toks, self.jnp.int32)[None]

    # -- legacy slot management ---------------------------------------------
    def acquire_slot(self, req: Request):
        if req.rid not in self.slot_of:
            if not self.free_slots:
                raise SlotCapacityError(
                    f"no free slot for {req.rid}: all {self.max_slots} "
                    "slots busy — raise max_slots or lower "
                    "EngineConfig.max_num_seqs")
            self.slot_of[req.rid] = self.free_slots.pop()
        return self.slot_of[req.rid]

    # finished-request token lists retained for post-run inspection
    # (parity tests, benchmarks); bounded so long-running serving does not
    # leak one list per completed request
    EMITTED_RETAIN = 4096

    def release_slot(self, req: Request):
        """Drop a request's executor-side state (engine calls this on
        preemption and on finish)."""
        self._ctx.pop(req.rid, None)
        if req.state in TERMINAL_STATES:
            # terminal (finished/rejected/failed/cancelled) requests never
            # run again: their profile memo and token arrays must not
            # outlive them (rejected ones carry the *largest* prompts)
            self._prompt_cache.pop(req.rid, None)
            self._isolated_ttft.pop(req.rid, None)
            if req.rid in self.emitted:
                self._finished_rids.append(req.rid)
                while len(self._finished_rids) > self.EMITTED_RETAIN:
                    self.emitted.pop(self._finished_rids.popleft(), None)
        else:
            # recompute-style preemption: the re-prefill re-emits the same
            # deterministic tokens from scratch
            self.emitted.pop(req.rid, None)
        slot = self.slot_of.pop(req.rid, None)
        if slot is not None:
            self.caches[slot] = self.jax.tree.map(
                lambda a: a * 0, self.caches[slot])
            self.free_slots.append(slot)

    # -- profiler interface -------------------------------------------------
    def isolated_run(self, req: Request) -> ProfileRecord:
        n = min(req.prompt_tokens, self.max_len - 8)
        meas = n
        t0 = time.perf_counter()
        if self.legacy:
            slot = self.acquire_slot(req)
            toks = self._tokens_for(req, 0, n)
            logits, cache, _ = self._legacy_jit(
                self.params, toks, None, self.caches[slot],
                self.jnp.int32(0))
            logits.block_until_ready()
            self.caches[slot] = cache
        else:
            rid = f"__profile__{req.rid}"
            # admission-time profiling borrows pages from the live pool; a
            # near-full pool must clamp the measurement, not crash serving.
            # Prefill is ~linear in tokens at these sizes (the residual
            # pricing in isolated_e2e already relies on that), so measure
            # the longest prefix that fits and extrapolate; a completely
            # full pool falls back to the last measured per-token rate.
            meas = min(n, self.allocator.available_pages
                       * self.allocator.page_size)
            if meas > 0:
                self.allocator.allocate(rid, meas)
                try:
                    toks = self._prompt_tokens(req)[:meas]
                    out = self._paged_prefill_call(
                        [(rid, toks, 0, 0, meas)])
                    out.block_until_ready()
                finally:
                    self.allocator.free(rid)
        prefill = time.perf_counter() - t0
        if meas < n:
            prefill = (prefill * n / meas if meas > 0
                       else getattr(self, "_profile_rate", 1e-4) * n)
        if n > 0 and meas > 0:
            self._profile_rate = prefill / n
        self.release_slot(req)
        self._prompt_cache.pop(req.rid, None)
        return ProfileRecord(
            modality=req.modality.value, text_tokens=req.text_tokens,
            mm_units=req.mm_units, prompt_tokens=req.prompt_tokens,
            preprocess_time=0.0, encode_time=0.0, prefill_time=prefill)

    def isolated_e2e(self, req: Request) -> float:
        ttft = self._isolated_ttft.get(req.rid)
        if ttft is None:
            ttft = self.isolated_run(req).ttft
            self._isolated_ttft[req.rid] = ttft
        cached = min(req.cached_prefix_tokens, max(req.prompt_tokens - 1, 0))
        if cached > 0 and req.prompt_tokens > 0:
            # measured prefill is ~linear in tokens at these sizes: price
            # only the residual the request will actually run
            ttft *= (req.prompt_tokens - cached) / req.prompt_tokens
        return ttft * (1 + 0.1 * req.output_tokens)

    def encode_chunk(self, req: Request, units: int) -> None:
        """Vision-encoder stage hook. The reduced models ship no real
        encoder, so this stands in with a chunk-sized JAX op — the engine
        clock still pays a *measured* per-chunk cost, and subclasses
        override this to run an actual encoder."""
        n = max(1, min(int(units), 256))
        x = self.jnp.ones((n, 32), self.jnp.float32)
        (x @ x.T).block_until_ready()

    # -- shared iteration-plan normalization --------------------------------
    # Both paths consume the engine's plan through the same row filters so
    # degenerate corners (mid-plan preemption, duplicate chunk entries,
    # context-window clamping) resolve identically — a requirement for the
    # bit-identical-token oracle.
    def _prefill_rows(self, prefill_work):
        """-> [(req, rope_start, n, emits_first_token)]."""
        rows = []
        est: dict[str, int] = {}
        for req, chunk in prefill_work:
            if self.allocator.owned_pages(req.rid) == 0:
                continue   # preempted later in the same planning pass
            start = est.get(req.rid, req.prefilled)
            est[req.rid] = start + chunk
            n = min(chunk, self.max_len - start - 4)
            if n <= 0:
                continue   # context window exhausted: no KV work possible
            # emit the first token either at the true prompt end or — for
            # prompts exceeding the context window — at the last in-window
            # chunk, so over-window requests still enter the decode path
            # (and pay real decode compute) instead of being dropped
            done = (start + chunk >= req.prompt_tokens
                    or start + n >= self.max_len - 4)
            rows.append((req, start, n, done))
        return rows

    def _decode_rows(self, decode_reqs):
        rows = []
        for req in decode_reqs:
            if (self.allocator.owned_pages(req.rid) == 0
                    or req.rid not in self._ctx
                    or not self.emitted.get(req.rid)):
                continue   # preempted mid-plan / never finished prefill
            rows.append(req)
        return rows

    # -- engine interface ----------------------------------------------------
    def run_iteration(self, prefill_work, decode_reqs, encode_work) -> float:
        t0 = time.perf_counter()
        for req, units in encode_work:
            self.encode_chunk(req, units)
        step = self._legacy_iteration if self.legacy else \
            self._batched_iteration
        step(self._prefill_rows(prefill_work),
             self._decode_rows(decode_reqs))
        return time.perf_counter() - t0

    # -- legacy sequential path (token-parity oracle) ------------------------
    def _legacy_iteration(self, prefill_rows, decode_rows):
        jnp = self.jnp
        for req, rope_start, n, done in prefill_rows:
            # slot acquired only after the n>0 check: the seed's
            # `n <= 0: continue` leaked the just-acquired slot
            slot = self.acquire_slot(req)
            toks = self._tokens_for(req, rope_start, n)
            logits, cache, _ = self._legacy_jit(
                self.params, toks, None, self.caches[slot],
                jnp.int32(rope_start))
            self.caches[slot] = cache
            self._ctx[req.rid] = self._ctx.get(req.rid, 0) + n
            if done:
                tok = int(jnp.argmax(logits[0, n - 1]))
                self.emitted.setdefault(req.rid, []).append(tok)
        for req in decode_rows:
            slot = self.acquire_slot(req)
            pos = min(req.prompt_tokens + req.decoded - 1, self.max_len - 2)
            tok = jnp.full((1, 1), self.emitted[req.rid][-1], jnp.int32)
            logits, cache, _ = self._legacy_jit(
                self.params, tok, jnp.full((1, 1), pos, jnp.int32),
                self.caches[slot], jnp.int32(pos))
            self.caches[slot] = cache
            self._ctx[req.rid] += 1
            self.emitted[req.rid].append(int(jnp.argmax(logits[0, 0])))

    # -- batched paged path ---------------------------------------------------
    @staticmethod
    def _bucket(n: int) -> int:
        return 1 << max(0, (n - 1).bit_length())

    def _prefill_step(self, params, stores, tokens, positions, bt, lengths,
                      new_lens):
        jnp = self.jnp
        cache = {"stages": stores, "block_table": bt, "lengths": lengths,
                 "new_lens": new_lens}
        last = jnp.maximum(new_lens - 1, 0)
        logits, new_cache, _ = self.T.forward(
            params, self.cfg, tokens, positions=positions, cache=cache,
            last_pos=last, attn_impl=self.attn_impl)
        return (jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32),
                new_cache["stages"])

    def _block_table_rows(self, rids, maxp: int) -> np.ndarray:
        trash = self.allocator.num_pages
        bt = np.full((len(rids), maxp), trash, np.int32)
        for i, rid in enumerate(rids):
            pages = self.allocator.pages_of(rid)[:maxp]
            bt[i, :len(pages)] = pages
        return bt

    def _paged_prefill_call(self, rows):
        """rows: [(rid, tokens ndarray, rope_start, write_start, n)].
        Runs one packed jit'd prefill step; returns last-token ids (B,)."""
        jnp = self.jnp
        if self._stores is None:
            self._stores = self._make_stores()
        B = self._bucket(len(rows))
        C = self._bucket(max(n for *_x, n in rows))
        page = self.allocator.page_size
        # live pages after this call's writes: ceil((write_start+n)/page)
        maxp = self._bucket_pages(
            max(-(-(ws + n) // page) for _r, _t, _rs, ws, n in rows))
        self.recompile_keys.add(("prefill", B, C, maxp))
        toks = np.zeros((B, C), np.int32)
        pos = np.zeros((B, C), np.int32)
        lengths = np.zeros((B,), np.int32)
        new_lens = np.zeros((B,), np.int32)
        for i, (_rid, t, rope_start, write_start, n) in enumerate(rows):
            toks[i, :n] = t
            pos[i] = rope_start + np.arange(C)
            lengths[i] = write_start
            new_lens[i] = n
        bt = np.full((B, maxp), self.allocator.num_pages, np.int32)
        bt[:len(rows)] = self._block_table_rows([r[0] for r in rows], maxp)
        out, self._stores = self._prefill_jit(
            self.params, self._stores, jnp.asarray(toks), jnp.asarray(pos),
            jnp.asarray(bt), jnp.asarray(lengths), jnp.asarray(new_lens))
        return out

    def _batched_iteration(self, prefill_rows, decode_rows):
        jnp = self.jnp
        # waves: a request may legitimately appear twice in one plan
        # (preempted then re-admitted); its chunks must apply in order and
        # never share one scatter (duplicate indices are unordered)
        waves: list[list] = []
        seen_at: dict[str, int] = {}
        for row in prefill_rows:
            w = seen_at.get(row[0].rid, -1) + 1
            seen_at[row[0].rid] = w
            if w == len(waves):
                waves.append([])
            waves[w].append(row)
        for wave in waves:
            # write_start read per wave: a later wave of the same request
            # starts where the previous wave's writes ended
            rows = [(req.rid, self._prompt_tokens(req)[rope:rope + n],
                     rope, self._ctx.get(req.rid, 0), n)
                    for req, rope, n, _d in wave]
            out = self._paged_prefill_call(rows)
            out = np.asarray(out)
            for i, (req, _rope, n, done) in enumerate(wave):
                self._ctx[req.rid] = rows[i][3] + n
                if done:
                    self.emitted.setdefault(req.rid, []).append(int(out[i]))
        if not decode_rows:
            return
        if self._stores is None:
            self._stores = self._make_stores()
        B = self._bucket(len(decode_rows))
        page = self.allocator.page_size
        # each row writes one token at position ctx, so the live context
        # after the step is ctx+1 tokens
        maxp = self._bucket_pages(
            max(-(-(self._ctx[r.rid] + 1) // page) for r in decode_rows))
        self.recompile_keys.add(("decode", B, maxp))
        toks = np.zeros((B, 1), np.int32)
        pos = np.zeros((B, 1), np.int32)
        lengths = np.zeros((B,), np.int32)
        new_lens = np.zeros((B,), np.int32)
        for i, req in enumerate(decode_rows):
            toks[i, 0] = self.emitted[req.rid][-1]
            pos[i, 0] = min(req.prompt_tokens + req.decoded - 1,
                            self.max_len - 2)
            lengths[i] = self._ctx[req.rid]
            new_lens[i] = 1
        bt = np.full((B, maxp), self.allocator.num_pages, np.int32)
        bt[:len(decode_rows)] = self._block_table_rows(
            [r.rid for r in decode_rows], maxp)
        out, self._stores = self._prefill_jit(
            self.params, self._stores, jnp.asarray(toks), jnp.asarray(pos),
            jnp.asarray(bt), jnp.asarray(lengths), jnp.asarray(new_lens))
        out = np.asarray(out)
        for i, req in enumerate(decode_rows):
            self._ctx[req.rid] += 1
            self.emitted[req.rid].append(int(out[i]))
