"""Continuous-batching serving engine (vLLM-style) with pluggable
scheduling policy — the substrate TCM-Serve plugs into.

Per iteration (vLLM V1 semantics with chunked prefill):
  1. ingest arrivals: classify (estimator+classifier), assign SLO, enqueue;
  2. the policy orders waiting+preempted requests; the engine admits them
     under the iteration token budget (decode tokens first, then prefill
     chunks) and the KV page allocator; under memory pressure the policy
     picks preemption victims (recompute-style eviction, as vLLM);
  3. the executor runs the batch (sim cost model or real JAX) and the clock
     advances; a request's preprocess+encode stage runs with its first
     prefill chunk (paper Fig. 6 TTFT decomposition);
  4. requests finishing prefill emit their first token that iteration
     (TTFT); decoding requests emit one token per iteration.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.cache.allocator import BlockAllocator
from repro.core.queues import QueueManager
from repro.core.scheduler import SchedulerPolicy
from repro.serving.request import Request, State, VehicleClass


@dataclass
class EngineConfig:
    token_budget: int = 2048        # chunked-prefill budget per iteration
    max_num_seqs: int = 64          # max concurrently running requests
    kv_pages: int = 24576           # KV capacity (pages); ~393k tokens at
    page_size: int = 16             # 16 tok/page (A100-40GB, 7B-class model)
    slo_scale: float = 5.0          # SLO = scale x isolated E2E (paper)
    max_preemptions_per_iter: int = 4
    # beyond-paper (EXPERIMENTS §Serving-perf): while latency-critical
    # (motorcycle) requests are decoding, shrink the prefill share of the
    # iteration so their inter-token latency stays near isolated speed.
    decode_priority: bool = False
    decode_priority_frac: float = 0.6


@dataclass
class Engine:
    policy: SchedulerPolicy
    executor: object
    classifier: object
    config: EngineConfig = field(default_factory=EngineConfig)

    def __post_init__(self):
        self.allocator = BlockAllocator(self.config.kv_pages,
                                        self.config.page_size)
        self.queues = QueueManager()
        self.now = 0.0
        self.running: list[Request] = []     # decoding
        self.prefilling: list[Request] = []  # admitted, chunked prefill
        self.finished: list[Request] = []
        self.rejected: list[Request] = []    # admission control
        self.iterations = 0

    # ------------------------------------------------------------------
    def _ingest(self, pending: list[Request]) -> list[Request]:
        """Move arrived requests into the classified waiting queues."""
        still = []
        for req in pending:
            if req.arrival <= self.now:
                vclass, est_prefill, est_kv = self.classifier.classify(
                    req.modality.value, req.text_tokens, req.mm_units)
                req.vclass = vclass
                req.est_prefill = est_prefill
                req.est_kv_tokens = est_kv
                # multimodal preprocess runs async on CPU (vLLM-style):
                # delays this request's readiness, not the GPU
                pre = getattr(self.executor, "preprocess_delay",
                              lambda r: 0.0)(req)
                req.preprocess_time = pre
                req.ready_at = req.arrival + pre
                if req.slo == float("inf"):
                    req.slo = self.config.slo_scale * \
                        self.executor.isolated_e2e(req)
                # admission control: a request whose context can never fit the
                # total KV capacity is rejected up front (vLLM errors out)
                need = req.prompt_tokens + req.output_tokens
                if self.allocator.pages_for_tokens(need) > \
                        self.allocator.num_pages:
                    req.state = State.REJECTED
                    self.rejected.append(req)
                    continue
                self.queues.push(req, self.now)
            else:
                still.append(req)
        return still

    # ------------------------------------------------------------------
    def _try_admit(self, req: Request) -> bool:
        """Allocate KV pages for the full prompt; preempt strictly
        lower-priority victims if needed (no preemption cycles)."""
        tokens = req.prompt_tokens
        tries = 0
        while not self.allocator.can_allocate(tokens):
            victim = self.policy.pick_victim(
                self.running + self.prefilling, self.now, for_req=req)
            if victim is None or victim is req or \
                    tries >= self.config.max_preemptions_per_iter:
                return False
            self._preempt(victim)
            tries += 1
        self.allocator.allocate(req.rid, tokens)
        return True

    def _preempt(self, victim: Request) -> None:
        """Recompute-style eviction: drop KV, back to the waiting queue."""
        self.allocator.free(victim.rid)
        if victim in self.running:
            self.running.remove(victim)
        if victim in self.prefilling:
            self.prefilling.remove(victim)
        if hasattr(self.executor, "release_slot"):
            self.executor.release_slot(victim)
        victim.preemptions += 1
        victim.preempted_at = self.now
        victim.prefilled = 0
        victim.state = State.PREEMPTED
        self.queues.push(victim, self.now)

    # ------------------------------------------------------------------
    def _plan(self):
        """Pick this iteration's decode batch + prefill chunks."""
        budget = self.config.token_budget
        decode_batch = list(self.running)
        budget -= len(decode_batch)
        if self.config.decode_priority and any(
                r.vclass is VehicleClass.MOTORCYCLE for r in decode_batch):
            # protect latency-critical inter-token latency: cap the prefill
            # share while motorcycles are decoding (beyond-paper)
            budget = min(budget, int(self.config.token_budget *
                                     self.config.decode_priority_frac))

        prefill_work: list[tuple[Request, int]] = []
        encode_batch: list[Request] = []

        # one policy-ordered pass over BOTH in-flight prefills and waiting
        # requests: lets a fresh motorcycle take budget ahead of a truck's
        # next chunk ("reshaping batches", paper §3.1) while admitted
        # requests keep their KV pages.
        candidates = self.policy.order(
            list(self.prefilling) +
            [r for r in self.queues.peek_all() if r.ready_at <= self.now],
            self.now)
        for req in candidates:
            if budget <= 0:
                break
            admitted = req in self.prefilling
            if not admitted:
                if len(self.running) + len(self.prefilling) >= \
                        self.config.max_num_seqs:
                    continue
                if not self._try_admit(req):
                    continue
                self.queues.remove(req)
                if req.preempted_at is not None:
                    req.preempted_time += self.now - req.preempted_at
                    req.preempted_at = None
                req.state = State.PREFILLING
                self.prefilling.append(req)
            elif req not in self.prefilling:
                continue  # got preempted by a later admission this pass
            if not req.stage_done:
                encode_batch.append(req)
                req.stage_done = True
            chunk = min(budget, req.prompt_tokens - req.prefilled)
            if chunk > 0:
                prefill_work.append((req, chunk))
                budget -= chunk
        return prefill_work, decode_batch, encode_batch

    # ------------------------------------------------------------------
    def step(self, pending: list[Request]) -> list[Request]:
        pending = self._ingest(pending)
        if not (self.running or self.prefilling or len(self.queues)):
            if pending:  # idle: jump to next arrival
                self.now = max(self.now, pending[0].arrival)
                pending = self._ingest(pending)
            else:
                return pending

        prefill_work, decode_batch, encode_batch = self._plan()
        if not (prefill_work or decode_batch or encode_batch) \
                and len(self.queues):
            # everything is waiting on async preprocess: jump ahead
            nxt = min(r.ready_at for r in self.queues.peek_all())
            self.now = max(self.now, nxt)
            prefill_work, decode_batch, encode_batch = self._plan()
        duration = self.executor.run_iteration(prefill_work, decode_batch,
                                               encode_batch)
        self.now += duration
        self.iterations += 1

        for req, chunk in prefill_work:
            if req not in self.prefilling:
                continue  # preempted later in the same planning pass
            req.prefilled += chunk
            if req.prefilled >= req.prompt_tokens:
                req.first_token_time = self.now  # prefill iter emits token 1
                req.decoded = 1
                req.state = State.RUNNING
                self.prefilling.remove(req)
                self.running.append(req)
        done = []
        for req in decode_batch:
            if req not in self.running:
                continue  # preempted mid-plan (defensive)
            req.decoded += 1
            # grow KV by one token; preempt someone if out of pages
            try:
                self.allocator.allocate(req.rid,
                                        req.prompt_tokens + req.decoded)
            except Exception:
                victim = self.policy.pick_victim(
                    [r for r in self.running + self.prefilling if r is not req],
                    self.now)
                if victim is not None:
                    self._preempt(victim)
                    self.allocator.allocate(
                        req.rid, req.prompt_tokens + req.decoded)
            if req.decoded >= req.output_tokens:
                done.append(req)
        for req in done:
            req.finish_time = self.now
            req.state = State.FINISHED
            self.running.remove(req)
            self.allocator.free(req.rid)
            if hasattr(self.executor, "release_slot"):
                self.executor.release_slot(req)
            self.finished.append(req)
        return pending

    # ------------------------------------------------------------------
    def run(self, requests: list[Request], max_iters: int = 2_000_000):
        pending = sorted(requests, key=lambda r: r.arrival)
        n = len(pending)
        it = 0
        while len(self.finished) + len(self.rejected) < n and it < max_iters:
            pending = self.step(pending)
            it += 1
        return self.finished
