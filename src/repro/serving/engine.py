"""Continuous-batching serving engine (vLLM-style) with pluggable
scheduling policy — the substrate TCM-Serve plugs into.

Per iteration (vLLM V1 semantics with chunked prefill):
  1. ingest arrivals: classify (estimator+classifier), assign SLO, enqueue —
     multimodal requests whose encoder output is not already cached enter
     the ENCODING stage (their own modality-aware queue) instead of the
     prefill queue;
  2. the encode plan draws encoding requests in policy rank order under a
     per-iteration patch budget (chunked, so a rock's encode is preemptible
     at chunk boundaries); the prefill plan orders waiting+preempted
     requests and admits them under the iteration token budget and the KV
     page allocator; under memory pressure the policy picks preemption
     victims (recompute-style eviction, as vLLM);
  3. the executor runs the batch (sim cost model or real JAX) and the clock
     advances; encode chunks overlap with LLM prefill/decode (max- rather
     than sum-composition of stage times, RServe-style);
  4. encode-complete requests move to the prefill queue; requests finishing
     prefill emit their first token that iteration (TTFT); decoding
     requests emit one token per iteration.

A ref-counted KV prefix cache spans the allocator, scheduler, and
executors (DESIGN.md §KV prefix cache): completed prefills publish their
page chains, later requests sharing a page-aligned prefix claim those
pages (copy-on-write at the boundary page) and prefill only the residual,
and the classifier/SLO rank them by that residual — so a duplicate video
(rock) competes like the sand its remaining work is. Cache hits change
*when* work happens, never what is emitted; ``prefix_cache=False`` and the
legacy paths below stay bit-identical oracles.

Scheduling bookkeeping is incremental (DESIGN.md §Incremental scheduling
core): the waiting set lives in a ``WaitingIndex`` consumed lazily in rank
order (no per-iteration global sort), running/prefilling membership is
O(1) (insertion-ordered dicts), KV grows only at page boundaries instead
of one allocator call per decoded token, and preemption probes go through
a rank-sorted ``VictimView``. ``EngineConfig.legacy_scheduling=True``
routes planning through the seed's brute-force path — kept as the
equivalence oracle and benchmark baseline; scheduling decisions are
bit-identical either way (benchmarks/scheduler_overhead.py enforces it).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field

from repro.cache import BlockAllocator, OutOfPages
from repro.core.queues import QueueManager
from repro.core.scheduler import SchedulerPolicy
from repro.serving.encoder_cache import EncoderCache
from repro.serving.journal import Journal
from repro.serving.request import (TERMINAL_STATES, Request, State,
                                   VehicleClass)


@dataclass
class EngineConfig:
    token_budget: int = 2048        # chunked-prefill budget per iteration
    max_num_seqs: int = 64          # max concurrently running requests
    kv_pages: int = 24576           # KV capacity (pages); ~393k tokens at
    page_size: int = 16             # 16 tok/page (A100-40GB, 7B-class model)
    slo_scale: float = 5.0          # SLO = scale x isolated E2E (paper)
    max_preemptions_per_iter: int = 4
    # beyond-paper (EXPERIMENTS §Serving-perf): while latency-critical
    # (motorcycle) requests are decoding, shrink the prefill share of the
    # iteration so their inter-token latency stays near isolated speed.
    decode_priority: bool = False
    decode_priority_frac: float = 0.6
    # decoupled vision-encode stage (ISSUE 2): per-iteration encode budget
    # in mm units (patches) — a rock's encode yields at chunk boundaries
    # instead of monopolizing the iteration. ~2048 patches costs about as
    # much as a full 512-token prefill budget on the calibrated model.
    encode_budget: int = 2048
    # encoder-output cache ("pebble cache"): dedup repeated mm inputs by
    # content hash; a hit skips the ENCODING stage entirely
    encoder_cache: bool = True
    encoder_cache_entries: int = 256
    # KV prefix cache (ISSUE 4): completed prefills publish their page
    # chains into the allocator's content-keyed index; later requests
    # sharing a page-aligned prefix (same system prompt / same mm input)
    # claim those pages ref-counted instead of re-prefilling them, and the
    # scheduler ranks them by the *residual* prefill — a fully-cached
    # video drops from rock to sand priority. Only takes effect when the
    # executor can share KV (``supports_prefix_cache``); hits change when
    # work happens, never what is emitted.
    prefix_cache: bool = True
    prefix_residual_classify: bool = True   # ablation: rank by full cost
    # seed's brute-force planning (full re-sort + per-token allocate):
    # the decision-equivalence oracle and host-overhead baseline
    legacy_scheduling: bool = False
    # fault-tolerant lifecycle (ISSUE 6): bounded retry-with-backoff for
    # transient encoder/executor faults. The backoff is simulated clock
    # time (doubling per attempt); past the retry cap an encoder fault
    # fails the request, an executor fault fails the batch.
    max_encode_retries: int = 3
    max_step_retries: int = 3
    retry_backoff_s: float = 0.05
    # graceful load shed under *sustained* page pressure (admission
    # blocked on pages for shed_after_iters consecutive iterations):
    # shed waiting rocks first — trucks, then cars, never motorcycles —
    # so sand keeps flowing. Since ISSUE 8 this knob is a legacy alias:
    # it maps onto the brownout ladder (admission.legacy_shed_config)
    # with the shed stage only, reproducing the PR 6 cadence exactly.
    # Off by default: fault-free runs stay bit-identical.
    load_shed: bool = False
    shed_after_iters: int = 40
    # overload control (ISSUE 8): an AdmissionConfig installs the
    # SLO-aware admission controller (per-tenant token buckets, bounded
    # per-class queues, TTFT feasibility at ingest — refused requests go
    # terminal REJECTED through the exactly-once release path); a
    # BrownoutConfig tunes the graded-degradation ladder driven by
    # sustained page pressure. ``admission`` alone arms the default
    # ladder; both None (and load_shed off) = no overload control, the
    # bit-identical historical path.
    admission: object | None = None   # AdmissionConfig
    brownout: object | None = None    # BrownoutConfig
    # lifecycle journal (ISSUE 10): append-only log of every state
    # transition and resource acquire/release, replayable into a second
    # independent accounting oracle (serving/journal.py). Pure recording
    # — no RNG, no clock reads the engine acts on — so a journal-enabled
    # run stays bit-identical to the same run without it.
    journal: bool = False


@dataclass
class Engine:
    policy: SchedulerPolicy
    executor: object
    classifier: object
    config: EngineConfig = field(default_factory=EngineConfig)
    # fault-injection plan (serving/faults.py) or None. Every hook below
    # is gated on ``faults is not None`` so the fault-free hot path pays
    # a single pointer check; an installed-but-empty FaultPlan() changes
    # nothing either (tests/test_faults.py gates both bit-exactly).
    faults: object | None = None

    def __post_init__(self):
        if self.config.encode_budget <= 0:
            # a zero budget would strand ENCODING requests forever (the
            # run loop would spin empty iterations until max_iters)
            raise ValueError("encode_budget must be positive")
        self.allocator = BlockAllocator(self.config.kv_pages,
                                        self.config.page_size)
        # paged-executor plumbing: the engine's page lists ARE the
        # executor's block tables, so the executor adopts this allocator
        # (its page ids index the executor's paged KV stores directly)
        if hasattr(self.executor, "bind_allocator"):
            self.executor.bind_allocator(self.allocator)
        self.queues = QueueManager()
        self.now = 0.0
        # insertion-ordered sets (dict keys): O(1) membership/removal while
        # iterating in the same order the seed's lists did
        self.running: dict[Request, None] = {}     # decoding
        self.prefilling: dict[Request, None] = {}  # admitted, chunked prefill
        self.finished: list[Request] = []
        self.rejected: list[Request] = []          # admission control
        self.aborted: list[Request] = []           # FAILED / CANCELLED
        self.iterations = 0
        # hardened lifecycle (ISSUE 6): deadline min-heap (lazy deletion;
        # empty when no request carries a finite deadline, so the sweep
        # is O(1) on fault-free runs), encoder-cache pins held per rid
        self._deadline_heap: list[tuple[float, int, Request]] = []
        self._deadline_seq = 0
        self._enc_pins: dict[str, str] = {}        # rid -> pinned mm_hash
        self._admit_blocked = False
        self.shed_count = 0
        # overload control (ISSUE 8): admission controller + brownout
        # ladder; the legacy load_shed knob maps onto a shed-only ladder
        from repro.serving.admission import (AdmissionController,
                                             BrownoutConfig, BrownoutLadder,
                                             legacy_shed_config)
        self.admission = (AdmissionController(self.config.admission)
                          if self.config.admission is not None else None)
        bcfg = self.config.brownout
        if bcfg is None:
            if self.config.admission is not None:
                bcfg = BrownoutConfig()
            elif self.config.load_shed:
                bcfg = legacy_shed_config(self.config.shed_after_iters)
        self.ladder = BrownoutLadder(bcfg) if bcfg is not None else None
        # decoupled encode stage: its own per-class queue manager; ordering
        # reuses the policy's WaitingIndex on the fast path
        self.encode_queues = QueueManager()
        self.encoder_cache = (EncoderCache(self.config.encoder_cache_entries)
                              if self.config.encoder_cache else None)
        # KV prefix cache: needs an executor whose KV pages are actually
        # shareable (sim cost model, or the batched paged ModelExecutor;
        # the legacy dense-slot path keeps per-request caches and opts out)
        self.prefix_on = (self.config.prefix_cache and
                          getattr(self.executor, "supports_prefix_cache",
                                  True))
        # publication gate: shareable content ids seen at ingest. A chain
        # is only published through content at least two requests have
        # carried — a unique video's thousand-page chain that nothing
        # can ever match must not bloat the index or the eviction path
        # (without this, no-duplicate workloads paid ~5x scheduler host
        # overhead for zero hits)
        self._prefix_seen: dict[str, int] = {}
        # newest resident carrier per shareable head cid, for
        # retro-publication when its content turns popular
        self._cid_resident: dict[str, Request] = {}
        if self.config.legacy_scheduling:
            self.wait_index = None
            self.encode_index = None
        else:
            self.wait_index = self.policy.make_waiting_index()
            self.queues.listener = self.wait_index
            self.encode_index = self.policy.make_waiting_index()
            self.encode_queues.listener = self.encode_index
        self._victim_view = None
        self._victim_view_now = None
        # lifecycle journal (ISSUE 10): every hook below is gated on
        # ``journal is not None`` — one pointer check on the hot path
        self.journal = Journal() if self.config.journal else None

    def _jrec(self, kind: str, rid: str, data=None) -> None:
        self.journal.record(self.now, kind, rid, data)

    # ------------------------------------------------------------------
    def _ingest(self, pending: list[Request], start: int = 0) -> int:
        """Move arrived requests into the classified waiting queues.
        ``pending`` is sorted by arrival; returns the new start index (the
        seed rebuilt the whole list every iteration — O(N) per step)."""
        i, n = start, len(pending)
        while i < n and pending[i].arrival <= self.now:
            req = pending[i]
            i += 1
            vclass, est_prefill, est_kv = self.classifier.classify(
                req.modality.value, req.text_tokens, req.mm_units)
            # KV prefix cache: an advisory match (pages are only claimed
            # at admission) re-classifies by the *residual* prefill — the
            # modality-aware analogue of automatic prefix caching: a
            # duplicate video's prompt is mostly cached KV, so it ranks
            # (and gets an SLO) like the sand it now is
            if self.prefix_on:
                crossed = False
                for cid, _n in req.content_chunks():
                    if "!" in cid:
                        break
                    n_seen = self._prefix_seen.get(cid, 0) + 1
                    self._prefix_seen[cid] = n_seen
                    crossed |= n_seen == 2
                if crossed and self._publish_ok():
                    # this arrival just made some prefix content popular:
                    # if its first carrier is still resident, publish that
                    # chain now so THIS request can already claim it
                    self._retro_publish(req.content_chunks()[0][0])
            if self.prefix_on and self.config.prefix_residual_classify:
                match = self.allocator.match_prefix(
                    req.content_chunks(), self._prefix_limit(req))
                if match.tokens > 0:
                    # visible to isolated_e2e below (residual SLO); the
                    # admission-time claim overwrites it with the pages
                    # actually taken
                    req.cached_prefix_tokens = match.tokens
                    res_text, res_mm = req.residual_sizes(match.tokens)
                    vclass, est_prefill, est_kv = self.classifier.classify(
                        req.modality.value, res_text, res_mm)
            req.vclass = vclass
            req.est_prefill = est_prefill
            req.est_kv_tokens = est_kv
            # multimodal preprocess runs async on CPU (vLLM-style):
            # delays this request's readiness, not the GPU
            pre = getattr(self.executor, "preprocess_delay",
                          lambda r: 0.0)(req)
            req.preprocess_time = pre
            # a migrated request is not schedulable before its page-chain
            # transfer lands (ready_floor is 0.0 otherwise — bit-exact)
            req.ready_at = max(req.arrival + pre, req.ready_floor)
            if req.slo == float("inf"):
                req.slo = self.config.slo_scale * \
                    self.executor.isolated_e2e(req)
                req.slo_from_engine = True
            # admission control: a request whose context can never fit the
            # total KV capacity is rejected up front (vLLM errors out);
            # REJECTED rides the same exactly-once release path as every
            # other terminal state (_abort is a no-op-safe superset here)
            need = req.prompt_tokens + req.output_tokens
            if self.allocator.pages_for_tokens(need) > \
                    self.allocator.num_pages:
                self._abort(req, State.REJECTED,
                            f"CapacityExceeded: context of {need} tokens "
                            f"exceeds total KV capacity")
                continue
            # SLO-aware admission (ISSUE 8): bounded queues, tenant
            # budget, TTFT feasibility against current backlog — all
            # deterministic from engine state, so a replay re-derives
            # the identical rejection set. Runs before the deadline
            # heap / encoder pin so a refused request holds nothing.
            if self.admission is not None:
                reason = self.admission.decide(req, self)
                if reason is not None:
                    self._abort(req, State.REJECTED, reason)
                    continue
            # hardened lifecycle: plan-assigned deadline (absolute = rel
            # after arrival); caller-set deadlines are honored as-is
            if self.faults is not None and req.deadline == float("inf"):
                rel = self.faults.deadline_for(req)
                if rel is not None:
                    req.deadline = req.arrival + rel
            if req.deadline != float("inf"):
                self._deadline_seq += 1
                heapq.heappush(self._deadline_heap,
                               (req.deadline, self._deadline_seq, req))
            # pin the encoder-cache entry this request depends on: an
            # ingest hit must stay resident until the request is done
            # with its embeddings; a miss reserves the hash the pending
            # encode will insert. Released exactly once at terminal.
            if self.encoder_cache is not None and req.mm_hash is not None \
                    and req.mm_units > 0:
                self.encoder_cache.pin(req.mm_hash)
                self._enc_pins[req.rid] = req.mm_hash
                if self.journal is not None:
                    self._jrec("pin", req.rid, req.mm_hash)
            # multimodal requests encode before they can prefill; a cached
            # encoder output (same content hash) skips the stage entirely
            if req.mm_units > 0 and not self._encode_cached(req):
                req.state = State.ENCODING
                self.encode_queues.push(req, self.now)
                if self.journal is not None:
                    self._jrec("state", req.rid, State.ENCODING.value)
                if self.faults is not None and \
                        self.faults.should_cancel(req, "encoding"):
                    self._abort(req, State.CANCELLED, "client cancel "
                                "(encoding)")
            else:
                self.queues.push(req, self.now)
                if self.journal is not None:
                    self._jrec("state", req.rid, State.WAITING.value)
                if self.faults is not None and \
                        self.faults.should_cancel(req, "waiting"):
                    self._abort(req, State.CANCELLED, "client cancel "
                                "(waiting)")
        return i

    def _encode_cached(self, req: Request) -> bool:
        """Encoder-cache lookup at ingest; a hit marks the request
        fully encoded. Requests without a content hash bypass the cache."""
        if self.encoder_cache is None or req.mm_hash is None:
            return False
        if not self.encoder_cache.lookup(req.mm_hash):
            return False
        req.encode_cache_hit = True
        req.encoded_units = req.mm_units
        return True

    # -- hardened lifecycle (ISSUE 6) ----------------------------------
    def _unpin_encoder(self, req: Request) -> None:
        """Release the request's encoder-cache pin (exactly once)."""
        h = self._enc_pins.pop(req.rid, None)
        if h is not None and self.encoder_cache is not None:
            self.encoder_cache.unpin(h)
        if h is not None and self.journal is not None:
            self._jrec("unpin", req.rid, h)

    def _abort(self, req: Request, state: State, error: str) -> bool:
        """Move ``req`` to a terminal FAILED/CANCELLED/REJECTED state,
        releasing every held resource exactly once: queue/membership
        indices, KV pages (incl. shared prefix-cache refs and COW claims
        — the allocator's ref counts make ``free`` safe for shared
        chains), encoder-cache pins, and executor-side slots/state.
        Idempotent: a second abort of a terminal request is a no-op.
        Admission rejections arrive here *pre-enqueue* (state WAITING but
        not yet queued), hence the membership check on queue removal.

        A cancelled/expired request whose prefill had completed still
        holds *valid* prompt KV — publish the chain first (like
        preemption does) so the work is re-monetizable; a FAILED request
        publishes nothing (its KV is suspect by definition)."""
        if req.state in TERMINAL_STATES:
            return False
        prev = req.state
        if prev in (State.WAITING, State.PREEMPTED):
            if req in self.queues.queues[req.vclass]:
                self.queues.remove(req)
        elif prev is State.ENCODING:
            self.encode_queues.remove(req)
        elif prev is State.PREFILLING:
            self.prefilling.pop(req, None)
        elif prev is State.RUNNING:
            self.running.pop(req, None)
        if self._victim_view is not None:
            self._victim_view.discard(req)
        if state is State.CANCELLED and self.prefix_on and \
                req.prefilled >= req.prompt_tokens and \
                self.allocator.owned_pages(req.rid) > 0:
            self.allocator.publish_prefix(req.rid, req.content_chunks())
        self.allocator.free(req.rid)
        req.state = state
        req.error = error
        req.aborted_at = self.now
        if hasattr(self.executor, "release_slot"):
            self.executor.release_slot(req)
        self._unpin_encoder(req)
        (self.rejected if state is State.REJECTED
         else self.aborted).append(req)
        if self.journal is not None:
            self._jrec("release", req.rid)
            self._jrec("terminal", req.rid, state.value)
        return True

    def cancel(self, req: Request, reason: str = "client cancel") -> bool:
        """Public cancellation entry point (client disconnect): abort a
        non-terminal request and release everything it holds."""
        return self._abort(req, State.CANCELLED, reason)

    # -- fleet tier (ISSUE 9) ------------------------------------------
    def export_request(self, req: Request) -> bool:
        """Release every engine-side resource of a non-terminal request
        WITHOUT deciding its fate — the handoff half of drain, migration,
        and failover (the fleet re-dispatches the request elsewhere):
        queue / running / prefilling membership, KV pages (ref-aware, so
        shared prefix chains survive), encoder-cache pin, executor slot
        and per-request executor state, and the deadline-heap entry (a
        live source replica must never expire a request that now lives on
        another replica). Exactly-once via the same membership guards
        ``_abort`` uses; returns False for terminal requests (nothing to
        hand off) and for requests this engine does not hold."""
        if req.state in TERMINAL_STATES:
            return False
        prev = req.state
        if prev in (State.WAITING, State.PREEMPTED):
            # vclass is None until first ingest: a routed-but-never-
            # ingested request holds nothing here beyond the no-op
            # releases below
            if req.vclass is not None and \
                    req in self.queues.queues[req.vclass]:
                self.queues.remove(req)
        elif prev is State.ENCODING:
            if req in self.encode_queues.queues[req.vclass]:
                self.encode_queues.remove(req)
        elif prev is State.PREFILLING:
            self.prefilling.pop(req, None)
        elif prev is State.RUNNING:
            self.running.pop(req, None)
        if self._victim_view is not None:
            self._victim_view.discard(req)
        self.allocator.free(req.rid)
        if hasattr(self.executor, "release_slot"):
            self.executor.release_slot(req)
        if hasattr(self.executor, "evict_request"):
            # release_slot keeps non-terminal per-rid memos (the request
            # would normally run again HERE); an exported request never
            # does, so drop them on the source executor
            self.executor.evict_request(req.rid)
        self._unpin_encoder(req)
        if self._deadline_heap:
            self._deadline_heap = [e for e in self._deadline_heap
                                   if e[2] is not req]
            heapq.heapify(self._deadline_heap)
        if self.journal is not None:
            self._jrec("release", req.rid)
            self._jrec("export", req.rid)
        return True

    def _expire_deadlines(self) -> None:
        """Abort every non-terminal request whose hard deadline passed.
        Lazy-deleting min-heap: terminal entries pop through silently, so
        the sweep costs O(expired log n) — zero when no deadlines exist."""
        heap = self._deadline_heap
        while heap and heap[0][0] <= self.now:
            _dl, _seq, req = heapq.heappop(heap)
            if req.state not in TERMINAL_STATES:
                self._abort(req, State.CANCELLED,
                            f"deadline exceeded ({req.deadline:.3f}s)")

    def _shed_for_pressure(self) -> bool:
        """Shed stage of the brownout ladder (the absorbed PR 6 policy):
        sustained page pressure climbed past every graded rung, so drop
        the biggest waiting rock — trucks first, then cars, *never*
        motorcycles — and keep the sand flowing (modality-aware
        degradation). Shedding waiting (not running) requests wastes no
        completed work. Returns True when a victim was shed (the ladder
        half-resets its streak then, so shedding stays gradual)."""
        for vclass in (VehicleClass.TRUCK, VehicleClass.CAR):
            q = self.queues.queues[vclass]
            if not len(q):
                continue
            victim = max(q, key=lambda r: (r.est_kv_tokens, r.rid))
            self._abort(victim, State.FAILED,
                        "load shed: sustained page pressure")
            self.shed_count += 1
            return True
        return False

    # ------------------------------------------------------------------
    def _victims(self):
        """Rank-sorted running+prefilling view, rebuilt when the clock
        moves and patched incrementally on admit/preempt in between."""
        if self._victim_view is None or self._victim_view_now != self.now:
            pool = list(self.running) + list(self.prefilling)
            self._victim_view = self.policy.make_victim_view(pool, self.now)
            self._victim_view_now = self.now
        return self._victim_view

    def _popular_tokens(self, chunks) -> int:
        """Token length of the leading run of content ids at least two
        ingested requests have carried — the publishable prefix."""
        total = 0
        for cid, n in chunks:
            if "!" in cid or self._prefix_seen.get(cid, 0) < 2:
                break
            total += n
        return total

    def _publish_ok(self) -> bool:
        """Brownout rung 3 (ISSUE 8): pause popularity-gated prefix
        publication while the ladder holds this rung — index growth and
        its eviction bookkeeping are pure speculation under pressure.
        Preemption victims (and cancelled completed prefills) still
        self-publish: that is preservation of paid-for work, not a bet."""
        return self.ladder is None or not self.ladder.active("publication")

    def _retro_publish(self, head_cid: str) -> None:
        """Publish the still-resident first carrier of newly-popular
        content (its completion predated the popularity, so the gate
        skipped it then). Stale candidates — finished (pages freed) or
        preempted (KV dropped) — fail the guards and are ignored."""
        cand = self._cid_resident.get(head_cid)
        if cand is None or cand.prefilled < cand.prompt_tokens or \
                self.allocator.owned_pages(cand.rid) == 0:
            return
        popular = self._popular_tokens(cand.content_chunks())
        if popular > 0:
            self.allocator.publish_prefix(cand.rid, cand.content_chunks(),
                                          max_tokens=popular)

    def _prefix_limit(self, req: Request) -> int:
        """Max claimable prefix: the last prompt token must always run
        through the model (its logits emit the first output token), and
        the real executor cannot start a row past its context window."""
        limit = req.prompt_tokens - 1
        cap = getattr(self.executor, "prefix_token_limit", None)
        if cap is not None:
            limit = min(limit, cap)
        return limit

    def _try_admit(self, req: Request) -> bool:
        """Allocate KV pages for the full prompt — re-using any cached
        prefix chain ref-counted — preempting strictly lower-priority
        victims if needed (no preemption cycles). Preempting a victim
        only releases pages nobody else references, so the page math
        below is ref-aware throughout (``can_allocate`` counts evictable
        cached pages as free and ``allocate`` evicts them on demand)."""
        tokens = req.prompt_tokens
        match = None
        if self.prefix_on:
            match = self.allocator.match_prefix(
                req.content_chunks(), self._prefix_limit(req))
        tries = 0
        legacy = self.config.legacy_scheduling
        bar = None
        while not self.allocator.can_allocate(tokens, rid=req.rid,
                                              match=match):
            if tries >= self.config.max_preemptions_per_iter:
                self._admit_blocked = True
                return False
            if legacy:
                victim = self.policy.pick_victim(
                    list(self.running) + list(self.prefilling), self.now,
                    for_req=req)
            else:
                if bar is None:
                    bar = self.policy.rank(req, self.now)
                victim = self._victims().pick(bar=bar, exclude=req)
            if victim is None or victim is req:
                self._admit_blocked = True
                return False
            self._preempt(victim)
            tries += 1
        claimed, cow_dst = self.allocator.claim_prefix(req.rid, match)
        req.cached_prefix_tokens = claimed
        req.prefilled = claimed   # residual prefill only
        if claimed > 0 and hasattr(self.executor, "on_prefix_claim"):
            # the COW copy must read the donor before any later eviction
            # can hand its page out, so the hook runs pre-allocate
            self.executor.on_prefix_claim(
                req, claimed,
                match.cow_src if cow_dst is not None else None, cow_dst)
        self.allocator.allocate(req.rid, tokens)
        if self.journal is not None:
            # claim_prefix asserted the block table was empty, so the
            # post-allocate snapshot is exactly what this admission took
            self._jrec("acquire", req.rid,
                       tuple(self.allocator.pages_of(req.rid)))
        return True

    def _preempt(self, victim: Request) -> None:
        """Recompute-style eviction: drop KV, back to the waiting queue.
        A victim whose prefill had completed publishes its chain first
        (popularity-exempt: the one future request guaranteed to want
        these exact pages is the victim itself), so unless real pressure
        evicts them, re-admission re-claims instead of re-prefilling."""
        if self.prefix_on and victim.prefilled >= victim.prompt_tokens:
            self.allocator.publish_prefix(victim.rid,
                                          victim.content_chunks())
        self.allocator.free(victim.rid)
        self.running.pop(victim, None)
        self.prefilling.pop(victim, None)
        if self._victim_view is not None:
            self._victim_view.discard(victim)
        if hasattr(self.executor, "release_slot"):
            self.executor.release_slot(victim)
        victim.preemptions += 1
        victim.preempted_at = self.now
        victim.prefilled = 0
        victim.state = State.PREEMPTED
        self.queues.push(victim, self.now)
        if self.journal is not None:
            self._jrec("release", victim.rid)
            self._jrec("state", victim.rid, State.PREEMPTED.value)
        if self.faults is not None and \
                self.faults.should_cancel(victim, "preempted"):
            # client disconnected in the preemption window: the victim's
            # pages are already freed, so the abort only dequeues it
            self._abort(victim, State.CANCELLED,
                        "client cancel (preempted)")

    def _reprice(self, req: Request) -> None:
        """The admission-time claim diverged from the ingest advisory —
        the chain was evicted while the request queued (claim shrank) or
        published meanwhile (claim grew). Re-derive class and SLO from
        the pages actually claimed, so victim eligibility and SLO
        accounting track the work really left; caller-provided SLOs are
        never touched. Runs after the queue exit: mutating ``vclass``
        while queued would desync the per-class queues."""
        res_text, res_mm = req.residual_sizes(req.cached_prefix_tokens)
        req.vclass, req.est_prefill, req.est_kv_tokens = \
            self.classifier.classify(req.modality.value, res_text, res_mm)
        if req.slo_from_engine:
            req.slo = self.config.slo_scale * \
                self.executor.isolated_e2e(req)

    def _admit(self, req: Request) -> bool:
        """Waiting -> prefilling transition (shared by both plan paths).
        Caller checks the max_num_seqs cap first."""
        if req.state in TERMINAL_STATES:
            # cancelled/failed while a stale plan snapshot still listed
            # it (e.g. a mid-plan preemption cancel) — never resurrect
            return False
        advisory = req.cached_prefix_tokens
        if not self._try_admit(req):
            return False
        self.queues.remove(req)
        if self.prefix_on and self.config.prefix_residual_classify and \
                req.cached_prefix_tokens != advisory:
            self._reprice(req)
        if req.preempted_at is not None:
            req.preempted_time += self.now - req.preempted_at
            req.preempted_at = None
        if req.admit_time is None:
            req.admit_time = self.now
        req.state = State.PREFILLING
        self.prefilling[req] = None
        if self.journal is not None:
            self._jrec("state", req.rid, State.PREFILLING.value)
        if self._victim_view is not None and \
                self._victim_view_now == self.now:
            self._victim_view.add(req)
        return True

    # ------------------------------------------------------------------
    def _plan(self):
        """Pick this iteration's encode chunks, decode batch + prefill
        chunks."""
        encode_work = self._plan_encode()
        budget = self.config.token_budget
        decode_batch = list(self.running)
        budget -= len(decode_batch)
        if self.config.decode_priority and any(
                r.vclass is VehicleClass.MOTORCYCLE for r in decode_batch):
            # protect latency-critical inter-token latency: cap the prefill
            # share while motorcycles are decoding (beyond-paper)
            budget = min(budget, int(self.config.token_budget *
                                     self.config.decode_priority_frac))
        if self.config.legacy_scheduling:
            prefill_work = self._plan_prefill_legacy(budget)
        else:
            prefill_work = self._plan_prefill(budget)
        return prefill_work, decode_batch, encode_work

    def _plan_encode(self) -> list[tuple[Request, int]]:
        """Draw encoding requests in policy rank order and hand out encode
        chunks under the per-iteration patch budget. Nothing is held
        across iterations (no KV is allocated while encoding), so a
        higher-priority arrival simply takes the next iteration's budget
        first — rock encodes are preemptible at every chunk boundary."""
        budget = self.config.encode_budget
        work: list[tuple[Request, int]] = []
        if budget <= 0 or not len(self.encode_queues):
            return work
        # brownout rung 1 (ISSUE 8): under sustained pressure, cap each
        # truck's encode chunk — rocks still make progress, but can no
        # longer monopolize the patch budget pebbles/sand are waiting on
        truck_cap = None
        if self.ladder is not None and self.ladder.active("encode"):
            truck_cap = max(1, int(budget * self.ladder.cfg.encode_chunk_frac))

        def _chunk(req: Request) -> int:
            chunk = min(budget, req.mm_units - req.encoded_units)
            if truck_cap is not None and req.vclass is VehicleClass.TRUCK:
                chunk = min(chunk, truck_cap)
            return chunk

        if self.config.legacy_scheduling:
            ordered = self.policy.order(
                [r for r in self.encode_queues.peek_all()
                 if r.ready_at <= self.now], self.now)
            for req in ordered:
                if budget <= 0:
                    break
                chunk = _chunk(req)
                if chunk > 0:
                    work.append((req, chunk))
                    budget -= chunk
            return work
        idx = self.encode_index
        idx.begin_plan(self.now)
        try:
            while budget > 0:
                head = idx.next_candidate(self.now)
                if head is None:
                    break
                chunk = _chunk(head[1])
                if chunk > 0:
                    work.append((head[1], chunk))
                    budget -= chunk
        finally:
            idx.end_plan()
        return work

    def _plan_prefill(self, budget: int):
        """One policy-ordered pass over BOTH in-flight prefills and waiting
        requests: lets a fresh motorcycle take budget ahead of a truck's
        next chunk ("reshaping batches", paper §3.1) while admitted
        requests keep their KV pages.

        The waiting set is drawn lazily from the WaitingIndex — only as
        many candidates as the budget/admission allows are ever ranked —
        and merged with a rank-sorted snapshot of the (small, capped)
        prefilling set. Ties resolve prefilling-first, exactly like the
        seed's stable sort over [prefilling] + [waiting]."""
        prefill_work: list[tuple[Request, int]] = []
        if budget <= 0:
            return prefill_work
        policy, now, cap = self.policy, self.now, self.config.max_num_seqs
        # brownout rung 2 (ISSUE 8): defer admitting *waiting* trucks
        # while the ladder holds this rung — trucks already prefilling
        # keep their pages and continue (no wasted work)
        defer_trucks = (self.ladder is not None
                        and self.ladder.active("defer_trucks"))
        pre = sorted((policy.rank(r, now), i, r)
                     for i, r in enumerate(self.prefilling))
        pi, npre = 0, len(pre)
        idx = self.wait_index
        idx.begin_plan(now)
        try:
            head = idx.next_candidate(now)
            while budget > 0:
                if head is not None and (pi >= npre or
                                         head[0] < pre[pi][0]):
                    req = head[1]
                    if defer_trucks and req.vclass is VehicleClass.TRUCK \
                            and req not in self.prefilling:
                        head = idx.next_candidate(now)
                        continue
                    if len(self.running) + len(self.prefilling) >= cap:
                        # no later waiting candidate can admit either; the
                        # seed scanned and skipped them all (side-effect
                        # free), so stop drawing from the index
                        head = None
                        continue
                    if not self._admit(req):
                        head = idx.next_candidate(now)
                        continue
                    head = idx.next_candidate(now)
                elif pi < npre:
                    req = pre[pi][2]
                    pi += 1
                    if req not in self.prefilling:
                        # preempted earlier in this pass; the seed re-ran
                        # such snapshot entries through the waiting branch
                        if len(self.running) + len(self.prefilling) >= cap \
                                or not self._admit(req):
                            continue
                else:
                    break
                chunk = min(budget, req.prompt_tokens - req.prefilled)
                if chunk > 0:
                    prefill_work.append((req, chunk))
                    budget -= chunk
        finally:
            idx.end_plan()
        return prefill_work

    def _plan_prefill_legacy(self, budget: int):
        """Seed behaviour: re-sort the full candidate set every iteration
        (the host-overhead baseline the incremental path is measured
        against; decisions are identical)."""
        prefill_work: list[tuple[Request, int]] = []
        defer_trucks = (self.ladder is not None
                        and self.ladder.active("defer_trucks"))
        candidates = self.policy.order(
            list(self.prefilling) +
            [r for r in self.queues.peek_all() if r.ready_at <= self.now],
            self.now)
        for req in candidates:
            if budget <= 0:
                break
            if req not in self.prefilling:
                if defer_trucks and req.vclass is VehicleClass.TRUCK:
                    continue
                if len(self.running) + len(self.prefilling) >= \
                        self.config.max_num_seqs:
                    continue
                if not self._admit(req):
                    continue
            chunk = min(budget, req.prompt_tokens - req.prefilled)
            if chunk > 0:
                prefill_work.append((req, chunk))
                budget -= chunk
        return prefill_work

    # ------------------------------------------------------------------
    def _grow_kv(self, req: Request, total_tokens: int) -> bool:
        """Grow a decoding request's KV to ``total_tokens``. On pressure,
        preempt a strictly-eligible victim; with no victim (or if the
        retry still fails), preempt the request itself recompute-style —
        the seed crashed on an uncaught OutOfPages here.

        Livelock guard (ISSUE 6 satellite): a context that can never fit
        *total* KV capacity would be preempted, re-admitted, re-prefilled
        and re-preempted at the same point forever. Detect "cannot fit
        even from an empty allocator" up front and fail the request with
        a clear CapacityExceeded error instead — no victim can help, so
        none is punished either."""
        try:
            fresh = self.allocator.allocate(req.rid, total_tokens)
            if fresh and self.journal is not None:
                self._jrec("acquire", req.rid, tuple(fresh))
            return True
        except OutOfPages:
            pass
        if self.allocator.pages_for_tokens(total_tokens) > \
                self.allocator.num_pages:
            self._abort(
                req, State.FAILED,
                f"CapacityExceeded: context of {total_tokens} tokens "
                f"needs {self.allocator.pages_for_tokens(total_tokens)} "
                f"pages but the allocator only has "
                f"{self.allocator.num_pages}")
            return False
        if self.config.legacy_scheduling:
            victim = self.policy.pick_victim(
                [r for r in list(self.running) + list(self.prefilling)
                 if r is not req], self.now)
        else:
            victim = self._victims().pick(exclude=req)
        if victim is not None:
            self._preempt(victim)
            try:
                fresh = self.allocator.allocate(req.rid, total_tokens)
                if fresh and self.journal is not None:
                    self._jrec("acquire", req.rid, tuple(fresh))
                return True
            except OutOfPages:
                pass
        self._preempt(req)
        return False

    def _step_core(self, pending: list[Request], start: int) -> int:
        start = self._ingest(pending, start)
        if self._deadline_heap:
            self._expire_deadlines()
        if not (self.running or self.prefilling or len(self.queues)
                or len(self.encode_queues)):
            if start < len(pending):  # idle: jump to next arrival
                self.now = max(self.now, pending[start].arrival)
                start = self._ingest(pending, start)
            else:
                return start

        self._admit_blocked = False
        prefill_work, decode_batch, encode_work = self._plan()
        if self.ladder is not None:
            # one degradation ladder (ISSUE 8): graded rungs first
            # (encode shrink / truck deferral / publication tightening
            # are applied inside the planners via ladder.active), shed
            # only at the top — with hysteresis on the way down
            if self.ladder.observe(self._admit_blocked) and \
                    self._shed_for_pressure():
                self.ladder.shed_fired()
        if not (prefill_work or decode_batch or encode_work) \
                and (len(self.queues) or len(self.encode_queues)):
            # everything is waiting on async preprocess: jump ahead
            nxt = min(r.ready_at for r in self.queues.peek_all()
                      + self.encode_queues.peek_all())
            self.now = max(self.now, nxt)
            prefill_work, decode_batch, encode_work = self._plan()
        if self.faults is not None:
            # transient executor-step faults: retry with doubling backoff
            # (simulated clock time); past the cap the fault is permanent
            # for this batch — fail every request the broken step touched
            attempt = 0
            while self.faults.step_fault(self.iterations, attempt):
                if attempt >= self.config.max_step_retries:
                    self.iterations += 1
                    for req, _chunk in prefill_work:
                        req.step_faults += 1
                        self._abort(req, State.FAILED, "executor fault "
                                    "(step retries exhausted)")
                    for req in decode_batch:
                        req.step_faults += 1
                        self._abort(req, State.FAILED, "executor fault "
                                    "(step retries exhausted)")
                    for req, _units in encode_work:
                        req.step_faults += 1
                        self._abort(req, State.FAILED, "executor fault "
                                    "(step retries exhausted)")
                    return start
                self.now += self.config.retry_backoff_s * (2 ** attempt)
                attempt += 1
        plan_now = self.now
        duration = self.executor.run_iteration(prefill_work, decode_batch,
                                               encode_work)
        self.now += duration
        self.iterations += 1

        cache = self.encoder_cache
        for req, units in encode_work:
            if self.faults is not None and self.faults.encoder_fault(req):
                # this chunk's encode failed (corrupt frame, encoder OOM):
                # no unit credit; requeue with doubling backoff, and fail
                # the request terminally once the retry budget is spent
                req.encode_faults += 1
                if req.encode_faults > self.config.max_encode_retries:
                    self._abort(req, State.FAILED,
                                "encoder fault (retries exhausted)")
                else:
                    self.encode_queues.remove(req)
                    req.ready_at = self.now + self.config.retry_backoff_s \
                        * (2 ** (req.encode_faults - 1))
                    self.encode_queues.push(req, self.now)
                continue
            if req.encode_start_time is None:
                req.encode_start_time = plan_now
            req.encoded_units += units
            if req.encoded_units >= req.mm_units:
                # encode complete: leave the encode queue, enter the
                # prefill queue; the freshly-encoded output becomes
                # cacheable for later duplicates
                req.encode_finish_time = self.now
                self.encode_queues.remove(req)
                if cache is not None and req.mm_hash is not None:
                    cache.insert(req.mm_hash, req.mm_units)
                req.state = State.WAITING
                self.queues.push(req, self.now)
                if self.journal is not None:
                    self._jrec("state", req.rid, State.WAITING.value)
                if self.faults is not None and \
                        self.faults.should_cancel(req, "waiting"):
                    self._abort(req, State.CANCELLED, "client cancel "
                                "(waiting)")
        page = self.config.page_size
        legacy = self.config.legacy_scheduling
        alloc = self.allocator
        for req, chunk in prefill_work:
            if req not in self.prefilling:
                continue  # preempted later in the same planning pass
            if self.faults is not None and \
                    self.faults.should_cancel(req, "prefilling"):
                # disconnect mid-prefill (possibly holding a COW claim on
                # shared prefix pages — _abort's ref-aware free handles it)
                self._abort(req, State.CANCELLED,
                            "client cancel (prefilling)")
                continue
            req.prefilled += chunk
            if self.prefix_on and req.prefilled < req.prompt_tokens \
                    and self._publish_ok():
                # progressive in-flight publication: pages this chunk
                # completed are final KV — publishing popular content as
                # it lands lets a duplicate admitted mid-prefill already
                # share the written prefix instead of racing a second
                # full prefill (gated, so one-off content costs nothing)
                chunks = req.content_chunks()
                popular = min(self._popular_tokens(chunks),
                              (req.prefilled // page) * page)
                if popular > 0:
                    self.allocator.publish_prefix(req.rid, chunks,
                                                  max_tokens=popular)
            if req.prefilled >= req.prompt_tokens:
                req.first_token_time = self.now  # prefill iter emits token 1
                req.decoded = 1
                req.state = State.RUNNING
                del self.prefilling[req]
                self.running[req] = None
                if self.journal is not None:
                    self._jrec("state", req.rid, State.RUNNING.value)
                if self.prefix_on:
                    # the prompt KV is final (decode writes only past it):
                    # publish the page chain for later requests, truncated
                    # to the popular prefix (content ids at least two
                    # requests have carried) so one-off content never
                    # grows the index; register as the resident carrier
                    # for retro-publication if popularity comes later
                    chunks = req.content_chunks()
                    if chunks and "!" not in chunks[0][0]:
                        self._cid_resident[chunks[0][0]] = req
                    popular = (self._popular_tokens(chunks)
                               if self._publish_ok() else 0)
                    if popular > 0:
                        self.allocator.publish_prefix(req.rid, chunks,
                                                      max_tokens=popular)
                # paged coverage: next iteration's decode writes KV at
                # position prompt_tokens, so when the prompt exactly fills
                # its pages the admission allocation has no slack — grow
                # now (post-decode growth keeps the invariant thereafter)
                if req.prompt_tokens + 1 > page * alloc.owned_pages(req.rid):
                    self._grow_kv(req, req.prompt_tokens + 1)
        done = []
        for req in decode_batch:
            if req not in self.running:
                continue  # preempted mid-plan (defensive)
            if self.faults is not None and \
                    self.faults.should_cancel(req, "running"):
                self._abort(req, State.CANCELLED,
                            "client cancel (running)")
                continue
            req.decoded += 1
            total = req.prompt_tokens + req.decoded
            # KV grows only when the context outruns the pages already
            # owned (the first token after prefill rides the admission
            # allocation's slack); the seed called allocate() every token
            if (legacy or total > page * alloc.owned_pages(req.rid)) and \
                    not self._grow_kv(req, total):
                continue  # req itself was preempted (recompute)
            if req.decoded >= req.output_tokens:
                done.append(req)
        for req in done:
            if req not in self.running:
                continue  # evicted by a later decode-growth preemption
            req.finish_time = self.now
            req.state = State.FINISHED
            del self.running[req]
            self.allocator.free(req.rid)
            if self._victim_view is not None:
                self._victim_view.discard(req)
            if hasattr(self.executor, "release_slot"):
                self.executor.release_slot(req)
            self._unpin_encoder(req)
            self.finished.append(req)
            if self.journal is not None:
                self._jrec("release", req.rid)
                self._jrec("terminal", req.rid, State.FINISHED.value)
        return start

    def step(self, pending: list[Request]) -> list[Request]:
        # the cursor-based core needs arrival order; the seed's step
        # accepted any order, so sort defensively when the caller didn't
        if any(pending[i].arrival > pending[i + 1].arrival
               for i in range(len(pending) - 1)):
            pending = sorted(pending, key=lambda r: r.arrival)
        i = self._step_core(pending, 0)
        return pending[i:] if i else pending

    @property
    def idle(self) -> bool:
        """No in-flight work anywhere (the router's stepped co-simulation
        uses this to detect quiescent replicas)."""
        return not (self.running or self.prefilling or len(self.queues)
                    or len(self.encode_queues))

    def overload_state(self) -> dict:
        """Per-replica overload snapshot (ISSUE 8): the router's
        pressure-aware placement and the SLO benchmark report read this —
        and the fleet-scale open item will route on it."""
        return {
            "brownout_level": self.ladder.level if self.ladder else 0,
            "shed": self.shed_count,
            "rejected": len(self.rejected),
            "admission": (self.admission.describe()
                          if self.admission is not None else None),
            "queued": len(self.queues) + len(self.encode_queues),
        }

    # ------------------------------------------------------------------
    def run(self, requests: list[Request], max_iters: int = 2_000_000):
        pending = sorted(requests, key=lambda r: r.arrival)
        n = len(pending)
        start = 0
        it = 0
        while len(self.finished) + len(self.rejected) + \
                len(self.aborted) < n and it < max_iters:
            start = self._step_core(pending, start)
            it += 1
        return self.finished
