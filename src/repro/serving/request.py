"""Request model: lifecycle, per-request metrics, and modality metadata."""
from __future__ import annotations

import enum
from dataclasses import dataclass


class Modality(str, enum.Enum):
    TEXT = "text"
    IMAGE = "image"
    VIDEO = "video"
    AUDIO = "audio"


class VehicleClass(str, enum.Enum):
    """The paper's trucks-cars-motorcycles abstraction."""
    MOTORCYCLE = "motorcycle"
    CAR = "car"
    TRUCK = "truck"

    @property
    def static_priority(self) -> float:
        return {"motorcycle": 0.1, "car": 0.05, "truck": 0.0}[self.value]


class State(str, enum.Enum):
    WAITING = "waiting"
    ENCODING = "encoding"       # in the vision-encode queue (mm_units > 0)
    PREFILLING = "prefilling"   # admitted; chunked prefill in progress
    RUNNING = "running"         # decoding
    PREEMPTED = "preempted"
    FINISHED = "finished"
    REJECTED = "rejected"       # admission control: infeasible SLO, tenant
    #                             budget, bounded queue, or a context that
    #                             exceeds total KV capacity (see .error)
    FAILED = "failed"           # terminal: fault/capacity/shed (see .error)
    CANCELLED = "cancelled"     # terminal: client cancel / deadline expiry


#: states from which a request never leaves; every held resource (KV
#: pages, prefix-cache refs, encoder-cache pins, queue membership,
#: executor slots) must have been released exactly once on entry
TERMINAL_STATES = frozenset(
    {State.FINISHED, State.REJECTED, State.FAILED, State.CANCELLED})


@dataclass(eq=False)  # identity semantics: hashable, O(1) membership in the
class Request:        # engine's running/prefilling sets (rids are unique)
    rid: str
    modality: Modality
    arrival: float
    # input sizes (modality-specific): text tokens always; plus patches/frames
    text_tokens: int
    mm_units: int = 0          # image patches or video frames (0 for text)
    output_tokens: int = 32    # decode length target
    mm_hash: str | None = None  # content hash of the mm input (encoder-cache
    #                             key; None = uncacheable / no mm payload)
    # shared leading text (system prompt / few-shot template): identifies
    # content, so equal ids => equal tokens (KV prefix-cache key)
    shared_prefix_id: str | None = None
    shared_prefix_tokens: int = 0   # leading text tokens drawn from that id
    # multi-tenant client pool (ISSUE 8): the admission controller's
    # token buckets and the fairness metrics key on this; survives
    # redispatch (the client does not change when a replica dies)
    tenant: str = "default"

    # ---- derived / filled by the pipeline ----
    prompt_tokens: int = 0     # total LLM prompt tokens (text + mm embeds)
    preprocess_time: float = 0.0
    encode_time: float = 0.0

    # ---- estimator / classifier outputs ----
    est_prefill: float = 0.0
    est_kv_tokens: float = 0.0
    vclass: VehicleClass | None = None

    # ---- runtime state ----
    ready_at: float = 0.0      # arrival + async CPU preprocess (vLLM-style)
    state: State = State.WAITING
    prefilled: int = 0         # prompt tokens prefilled so far
    decoded: int = 0
    enqueue_time: float = 0.0  # when (re-)entered the waiting queue
    encoded_units: int = 0     # mm units encoded so far (chunked encode)
    encode_cache_hit: bool = False  # encoder output served from the cache
    cached_prefix_tokens: int = 0   # prompt tokens served from the KV
    #                                 prefix cache (advisory at ingest,
    #                                 actual claim at admission)

    # ---- metrics ----
    encode_start_time: float | None = None   # first encode chunk scheduled
    encode_finish_time: float | None = None  # last encode chunk completed
    admit_time: float | None = None          # first admission to prefilling
    first_token_time: float | None = None
    finish_time: float | None = None
    preemptions: int = 0
    preempted_time: float = 0.0
    preempted_at: float | None = None
    slo: float = float("inf")  # absolute latency target (seconds, e2e)
    slo_from_engine: bool = False  # engine-assigned (scale x isolated) vs
    #                                caller-provided: only the former may be
    #                                re-derived when cache state shifts
    # ---- fault-tolerant lifecycle (ISSUE 6) ----
    deadline: float = float("inf")  # absolute hard deadline (abort past it)
    error: str | None = None        # why the request FAILED / was CANCELLED
    aborted_at: float | None = None  # terminal-abort timestamp (finish_time
    #                                  stays None: an aborted request never
    #                                  produced its full output)
    encode_faults: int = 0          # injected encoder-chunk failures seen
    step_faults: int = 0            # executor-step retries charged to it
    redispatches: int = 0           # replica-failover re-dispatch count
    # ---- fleet tier / migration (ISSUE 9) ----
    migrations: int = 0             # live page-chain migrations survived
    ready_floor: float = 0.0        # earliest admissible time on the target
    #                                 replica: a migrated request only
    #                                 becomes schedulable once its chain
    #                                 transfer completes (absolute seconds;
    #                                 0.0 = no hold, the bit-exact default)
    _chunks_cache: tuple | None = None  # memoized content_chunks()

    @property
    def is_terminal(self) -> bool:
        return self.state in TERMINAL_STATES

    def reset_for_redispatch(self) -> None:
        """Restart the lifecycle on a surviving replica after its original
        replica died: all progress (encode, prefill, decode, cache claims)
        lived in the dead replica's memory and is gone. Arrival (and any
        caller-provided SLO/deadline) is preserved — the client has been
        waiting since then — while engine-assigned SLOs reset so the new
        replica re-derives them from its own cache state."""
        self.state = State.WAITING
        self.prefilled = 0
        self.decoded = 0
        self.encoded_units = 0
        self.encode_cache_hit = False
        self.cached_prefix_tokens = 0
        self.enqueue_time = 0.0
        self.ready_at = 0.0
        self.encode_start_time = None
        self.encode_finish_time = None
        self.admit_time = None
        self.first_token_time = None
        self.finish_time = None
        self.aborted_at = None
        self.error = None
        self.preempted_at = None
        self.encode_faults = 0
        self.ready_floor = 0.0   # migration may re-apply a transfer hold
        if self.slo_from_engine:
            self.slo = float("inf")
            self.slo_from_engine = False
        self.redispatches += 1

    def content_chunks(self) -> tuple:
        """The prompt as ``(content_id, tokens)`` segments in canonical
        MLLM order — [shared system prefix][mm payload][private text] —
        the structural identity the KV prefix cache hashes page-by-page.
        Ids are equal across requests exactly when the underlying content
        is (same system prompt / same mm input); private segments carry a
        ``!`` and the rid, so they can never match another request.
        Cached: the layout is fixed at construction and this sits on the
        per-request scheduling hot path."""
        if self._chunks_cache is not None:
            return self._chunks_cache
        chunks = []
        used = 0
        if self.shared_prefix_tokens > 0 and self.shared_prefix_id:
            n = min(self.shared_prefix_tokens, self.prompt_tokens)
            chunks.append((f"sys:{self.shared_prefix_id}", n))
            used += n
        if self.mm_units > 0 and used < self.prompt_tokens:
            cid = (f"mm:{self.mm_hash}" if self.mm_hash
                   else f"mm!{self.rid}")
            n = min(self.mm_units, self.prompt_tokens - used)
            chunks.append((cid, n))
            used += n
        if used < self.prompt_tokens:
            chunks.append((f"txt!{self.rid}", self.prompt_tokens - used))
        self._chunks_cache = tuple(chunks)
        return self._chunks_cache

    def residual_sizes(self, cached_tokens: int) -> tuple[int, int]:
        """(text_tokens, mm_units) NOT covered by a cached prefix of
        ``cached_tokens`` — what the classifier should rank: a fully
        cached video has the residual prefill of a text request."""
        rem_mm = 0
        off = 0
        for cid, n in self.content_chunks():
            if cid.startswith("mm"):
                rem_mm += n - max(0, min(n, cached_tokens - off))
            off += n
        rem_text = max(0, self.prompt_tokens - cached_tokens) - rem_mm
        return max(0, rem_text), max(0, rem_mm)

    def ttft(self) -> float | None:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival

    def e2e(self) -> float | None:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival

    def norm_latency(self) -> float | None:
        """Seconds per output token (the paper's 'normalized latency')."""
        e2e = self.e2e()
        if e2e is None or self.output_tokens == 0:
            return None
        return e2e / self.output_tokens

    def slo_violated(self) -> bool:
        e2e = self.e2e()
        return e2e is not None and e2e > self.slo

    def violation_severity(self) -> float:
        e2e = self.e2e()
        if e2e is None:
            return 0.0
        return max(0.0, e2e - self.slo)

    def waiting_time(self, now: float) -> float:
        return max(0.0, now - self.enqueue_time)

    def ttft_breakdown(self) -> dict | None:
        """TTFT split into pipeline stages (paper Fig. 6, but measured on
        the live engine rather than isolated runs): preprocess, encode
        queue wait, encode, prefill queue wait, and prefill — the prefill
        term absorbs preemption/requeue time after the first admission."""
        if self.first_token_time is None:
            return None
        pre = max(0.0, self.ready_at - self.arrival)
        if self.encode_start_time is not None:
            enc_end = self.encode_finish_time
            if enc_end is None:
                enc_end = self.encode_start_time
            enc_wait = max(0.0, self.encode_start_time - self.ready_at)
            enc = max(0.0, enc_end - self.encode_start_time)
            queued_from = enc_end
        else:  # text-only, or encoder-cache hit (encode skipped entirely)
            enc_wait = enc = 0.0
            queued_from = self.ready_at
        admit = self.admit_time
        if admit is None:
            admit = self.first_token_time
        return {
            "preprocess": pre,
            "encode_wait": enc_wait,
            "encode": enc,
            "queue_wait": max(0.0, admit - queued_from),
            "prefill": max(0.0, self.first_token_time - admit),
        }
