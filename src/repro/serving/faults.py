"""Deterministic fault injection for the serving tier (ISSUE 6 tentpole).

Production MLLM traffic is not fault-free: clients disconnect mid-stream,
encoders hit corrupt frames, executor steps fail transiently, deadlines
expire, and whole replicas die (ServeGen/ElasticMM, PAPERS.md). The engine
and router expose named injection points; a ``FaultPlan`` decides — purely
from a seed and per-request content — what fails where, so every chaos
scenario replays bit-identically: a failing schedule from a CI log is a
regression test, never a flake.

Injection points (the engine/router query these; ``None`` plan = no-op):

  * ``should_cancel(req, stage)`` — client cancellation/disconnect, fired
    the *n*-th time the engine observes the request in the sampled stage
    (waiting / encoding / prefilling / running / preempted — including
    mid-COW-claim and post-preemption windows).
  * ``deadline_for(req)`` — per-request hard deadline, seconds after
    arrival; the engine aborts expired requests exactly once.
  * ``encoder_fault(req)`` — this encode chunk fails; the engine retries
    with backoff up to ``EngineConfig.max_encode_retries``, then fails the
    request terminally.
  * ``step_fault(iteration, attempt)`` — transient executor-step fault;
    the engine retries the iteration with backoff up to
    ``EngineConfig.max_step_retries``, then fails the batch.
  * ``kill_time(replica)`` — whole-replica crash for the router's stepped
    co-simulation; in-flight requests are re-dispatched prefix-cache-aware
    to surviving replicas.
  * ``migration_fault(rid, chunk, attempt)`` — migration-domain faults
    (ISSUE 9): one bounded chunk of a page-chain transfer times out or
    arrives corrupted (checksum verification fails); the migrator retries
    with backoff, then falls back to residual re-prefill on the target.
    Source/target-dies-mid-transfer are not sampled here — they emerge
    when ``kill_time`` intersects the transfer window (serving/migration.py
    cuts the transfer off at the crash).

Determinism contract: per-request decisions are hashed from
``(seed, kind, rid)`` — independent of arrival order, scheduling, or how
many other requests exist — and per-iteration decisions from
``(seed, iteration)``. A plan is *stateful for one run* (it counts stage
observations and encode attempts); build a fresh plan with the same seed
to replay the identical schedule.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field

import numpy as np


class FaultError(RuntimeError):
    """Base class for injected / lifecycle faults."""


class CapacityExceeded(FaultError):
    """A request's context can never fit total KV capacity — retrying
    (self-preemption + re-admission) would livelock, so the engine fails
    the request terminally instead (ISSUE 6 satellite)."""


class EncoderFault(FaultError):
    """Injected vision-encoder chunk failure (corrupt frame, OOM, ...)."""


class ExecutorFault(FaultError):
    """Injected executor step failure (transient unless retries exhaust)."""


#: stages a sampled cancellation can target (State values the engine
#: observes at its transition checkpoints)
CANCEL_STAGES = ("waiting", "encoding", "prefilling", "running", "preempted")


@dataclass
class FaultRates:
    """Sampling knobs for ``FaultPlan.sample`` — probabilities are
    per-request (cancel/deadline/encoder) or per-iteration (step)."""
    cancel_prob: float = 0.0
    deadline_prob: float = 0.0
    encoder_fault_prob: float = 0.0
    step_fault_prob: float = 0.0
    # migration-domain (ISSUE 9): per-chunk probabilities that one bounded
    # chunk of a page-chain transfer times out / fails checksum verify
    migration_timeout_prob: float = 0.0
    migration_corrupt_prob: float = 0.0
    # a faulted request/iteration is *permanent* (outlasts every retry)
    # with this probability; otherwise it heals after 1-2 retries
    permanent_frac: float = 0.15
    # sampled deadlines: uniform seconds after arrival (tight enough that
    # some expire under load, loose enough that most do not)
    deadline_min_s: float = 2.0
    deadline_max_s: float = 60.0

    def scaled(self, f: float) -> "FaultRates":
        """The same shape of chaos at ``f``x the event rates (escalation
        schedule of benchmarks/fault_tolerance.py)."""
        return FaultRates(
            cancel_prob=min(1.0, self.cancel_prob * f),
            deadline_prob=min(1.0, self.deadline_prob * f),
            encoder_fault_prob=min(1.0, self.encoder_fault_prob * f),
            step_fault_prob=min(1.0, self.step_fault_prob * f),
            migration_timeout_prob=min(1.0, self.migration_timeout_prob * f),
            migration_corrupt_prob=min(1.0, self.migration_corrupt_prob * f),
            permanent_frac=self.permanent_frac,
            deadline_min_s=self.deadline_min_s,
            deadline_max_s=self.deadline_max_s)


# a retry count no schedule reaches: "permanent" faults fail every attempt
_PERMANENT = 1 << 20


@dataclass
class FaultPlan:
    """One run's fault schedule. Explicit injections (the ``cancels`` /
    ``deadlines`` / ``encoder_faults`` / ``step_faults`` /
    ``replica_kills`` maps) take precedence; anything not pinned
    explicitly is sampled from ``rates`` (all-zero by default, so
    ``FaultPlan()`` is the installed-but-inert layer used for the
    fault-free-parity gates)."""
    seed: int = 0
    rates: FaultRates = field(default_factory=FaultRates)
    # explicit injections -------------------------------------------------
    cancels: dict = field(default_factory=dict)        # rid -> (stage, nth)
    deadlines: dict = field(default_factory=dict)      # rid -> rel seconds
    encoder_faults: dict = field(default_factory=dict)  # rid -> n failures
    step_faults: dict = field(default_factory=dict)    # iter -> n failures
    replica_kills: dict = field(default_factory=dict)  # replica -> time
    # (rid, chunk) -> ("timeout"|"corrupt", n attempts it outlasts)
    migration_faults: dict = field(default_factory=dict)
    # crash recovery (ISSUE 10): replica -> seconds after its death that
    # a fresh engine restarts in its slot (rejoin is further gated by the
    # fleet's warm-up window). Absent replicas stay down forever — the
    # pre-ISSUE-10 behaviour, and the bit-exact default.
    restart_delays: dict = field(default_factory=dict)

    def __post_init__(self):
        # run-scoped observation state (see module docstring)
        self._stage_seen: dict[tuple[str, str], int] = {}
        self._encode_attempts: dict[str, int] = {}
        self._cancel_memo: dict[str, tuple | None] = {}
        self._deadline_memo: dict[str, float | None] = {}
        self._encoder_memo: dict[str, int] = {}
        self._step_memo: dict[int, int] = {}
        self._migration_memo: dict[tuple, tuple] = {}
        # counters (surfaced by the chaos benchmark)
        self.injected = {"cancel": 0, "deadline": 0, "encoder": 0,
                         "step": 0, "mig_timeout": 0, "mig_corrupt": 0}

    # -- deterministic per-key RNG ----------------------------------------
    def _rng(self, kind: str, key) -> np.random.Generator:
        h = zlib.crc32(f"{self.seed}:{kind}:{key}".encode()) & 0x7FFFFFFF
        return np.random.default_rng(h)

    def _severity(self, rng: np.random.Generator) -> int:
        """How many attempts a sampled fault outlasts."""
        if rng.uniform() < self.rates.permanent_frac:
            return _PERMANENT
        return int(rng.integers(1, 3))

    # -- cancellation ------------------------------------------------------
    def _cancel_point(self, rid: str) -> tuple | None:
        if rid in self._cancel_memo:
            return self._cancel_memo[rid]
        point = self.cancels.get(rid)
        if point is None and self.rates.cancel_prob > 0:
            rng = self._rng("cancel", rid)
            if rng.uniform() < self.rates.cancel_prob:
                stage = CANCEL_STAGES[int(rng.integers(len(CANCEL_STAGES)))]
                point = (stage, int(rng.integers(1, 4)))  # 1st..3rd sight
        self._cancel_memo[rid] = point
        return point

    def should_cancel(self, req, stage: str) -> bool:
        """True exactly once: the ``nth`` time ``req`` is observed in its
        sampled cancel stage."""
        point = self._cancel_point(req.rid)
        if point is None or point[0] != stage:
            return False
        seen = self._stage_seen.get((req.rid, stage), 0) + 1
        self._stage_seen[(req.rid, stage)] = seen
        if seen == point[1]:
            self.injected["cancel"] += 1
            return True
        return False

    # -- deadlines ---------------------------------------------------------
    def deadline_for(self, req) -> float | None:
        """Deadline in seconds after arrival, or None (no deadline)."""
        rid = req.rid
        if rid in self._deadline_memo:
            return self._deadline_memo[rid]
        rel = self.deadlines.get(rid)
        if rel is None and self.rates.deadline_prob > 0:
            rng = self._rng("deadline", rid)
            if rng.uniform() < self.rates.deadline_prob:
                rel = float(rng.uniform(self.rates.deadline_min_s,
                                        self.rates.deadline_max_s))
        if rel is not None:
            self.injected["deadline"] += 1
        self._deadline_memo[rid] = rel
        return rel

    # -- encoder chunk faults ----------------------------------------------
    def _encoder_failures(self, rid: str) -> int:
        n = self._encoder_memo.get(rid)
        if n is None:
            n = self.encoder_faults.get(rid, 0)
            if n == 0 and self.rates.encoder_fault_prob > 0:
                rng = self._rng("encoder", rid)
                if rng.uniform() < self.rates.encoder_fault_prob:
                    n = self._severity(rng)
            self._encoder_memo[rid] = n
        return n

    def encoder_fault(self, req) -> bool:
        """True while the request's sampled failure budget lasts: the
        first ``n`` encode chunks of a faulted request fail, then it
        heals (or never does, if permanent)."""
        n = self._encoder_failures(req.rid)
        if n <= 0:
            return False
        attempt = self._encode_attempts.get(req.rid, 0) + 1
        self._encode_attempts[req.rid] = attempt
        if attempt <= n:
            self.injected["encoder"] += 1
            return True
        return False

    # -- executor step faults ----------------------------------------------
    def step_fault(self, iteration: int, attempt: int) -> bool:
        """True while the iteration's sampled failure budget outlasts
        ``attempt`` (0-based retry counter within the iteration)."""
        n = self._step_memo.get(iteration)
        if n is None:
            n = self.step_faults.get(iteration, 0)
            if n == 0 and self.rates.step_fault_prob > 0:
                rng = self._rng("step", iteration)
                if rng.uniform() < self.rates.step_fault_prob:
                    n = self._severity(rng)
            self._step_memo[iteration] = n
        if attempt < n:
            self.injected["step"] += 1
            return True
        return False

    # -- replica crashes ---------------------------------------------------
    def kill_time(self, replica: int) -> float | None:
        return self.replica_kills.get(replica)

    def restart_delay(self, replica: int) -> float | None:
        """Seconds after death until a fresh engine restarts in this
        replica's slot (ISSUE 10), or None — it stays down."""
        return self.restart_delays.get(replica)

    # -- page-chain migration faults (ISSUE 9) -----------------------------
    def migration_fault(self, rid: str, chunk: int,
                        attempt: int) -> str | None:
        """Fault for transferring ``chunk`` of ``rid``'s page chain on
        (0-based) retry ``attempt``: ``"timeout"`` (the chunk never
        arrives within the chunk timeout), ``"corrupt"`` (it arrives but
        checksum verification rejects it), or None. Like every injection,
        hashed purely from (seed, kind, rid, chunk) so a replay sees the
        identical fault sequence regardless of when the migration runs."""
        key = (rid, chunk)
        ent = self._migration_memo.get(key)
        if ent is None:
            ent = self.migration_faults.get(key)
            if ent is None:
                kind, n = None, 0
                pt = self.rates.migration_timeout_prob
                pc = self.rates.migration_corrupt_prob
                if pt > 0 or pc > 0:
                    rng = self._rng("migration", f"{rid}:{chunk}")
                    u = rng.uniform()
                    if u < pt:
                        kind, n = "timeout", self._severity(rng)
                    elif u < pt + pc:
                        kind, n = "corrupt", self._severity(rng)
                ent = (kind, n)
            self._migration_memo[key] = ent
        kind, n = ent
        if kind is not None and attempt < n:
            self.injected["mig_" + kind] += 1
            return kind
        return None

    # -- reporting ---------------------------------------------------------
    def describe(self) -> dict:
        return {
            "seed": self.seed,
            "rates": vars(self.rates).copy(),
            "explicit": {
                "cancels": len(self.cancels),
                "deadlines": len(self.deadlines),
                "encoder_faults": len(self.encoder_faults),
                "step_faults": len(self.step_faults),
                "replica_kills": dict(self.replica_kills),
                "migration_faults": len(self.migration_faults),
                "restart_delays": dict(self.restart_delays),
            },
            "injected": dict(self.injected),
        }
