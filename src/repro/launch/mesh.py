"""Production mesh + logical-axis rule construction.

Target: TPU v5e. Single pod = 16x16 = 256 chips (data, model); multi-pod =
2 x 16 x 16 = 512 chips (pod, data, model). Function (not module constant)
so importing never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_rules(mode: str, *, multi_pod: bool = False,
               opts: frozenset | set = frozenset()) -> dict:
    """Logical-axis -> mesh-axis rules per execution mode.

    mode: 'train' | 'serve' | 'long_ctx'
    opts (hillclimb levers, EXPERIMENTS.md §Perf):
      'moe_data'  — shard MoE dispatch/expert tensors' group dim over data
                    (baseline replicates them -> per-layer all-gather)
      'seq_par'   — sequence parallelism for prefill: activations' seq dim
                    over the model axis (attention gathers the small GQA KV)
      'act_model' — shard saved train activations' d_model over model axis
    """
    data = ("pod", "data") if multi_pod else ("data",)
    base = {
        "batch": data,
        "seq": None,
        "embed": None,
        "embed_act": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "experts": None,
        "expert_mlp": "model",
        "vocab": "model",
        "conv": None,
        "state": None,
        "inner": "model",
        "cache_seq": None,
        "layers": None,
        "moe_group": None,
    }
    if mode == "train":
        # FSDP(ZeRO-3-style): params sharded along d_model over the data axis
        base["embed"] = data if multi_pod else "data"
    elif mode == "long_ctx":
        # batch=1: context parallelism — KV cache seq dim over the data axis
        base["batch"] = None
        base["cache_seq"] = data if multi_pod else "data"
    elif mode != "serve":
        raise ValueError(mode)
    if "moe_data" in opts:
        base["moe_group"] = data if multi_pod else "data"
    if "seq_par" in opts:
        base["seq"] = "model"
    if "seq_par_repl" in opts:
        # small-model long-prefill recipe: replicate weights (fits HBM),
        # use the model axis purely for sequence parallelism -> MLP fully
        # local; attention all-gathers only the small GQA KV
        base["seq"] = "model"
        for ax in ("heads", "kv_heads", "mlp", "vocab", "embed", "inner",
                   "expert_mlp"):
            base[ax] = None
    if "act_model" in opts:
        base["embed_act"] = "model"
    return base
