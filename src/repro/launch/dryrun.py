import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost/collective analysis.

MUST be run as its own process (the XLA_FLAGS line above precedes every
other import because jax locks the device count on first init).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-27b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --list   # show all pairs
Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import re
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs import ALIASES, get_config
from repro.launch.mesh import make_production_mesh, make_rules
from repro.models import transformer as T
from repro.models.config import pad_for_tp
from repro.models.params import abstract_params, param_count, param_pspecs
from repro.models.sharding import use_rules
from repro.train.loop import abstract_train_state, train_step
from repro.train.optimizer import AdamWState

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1, long=True),
}

# long_500k runs only where attention state is sub-quadratic / bounded
# (see DESIGN.md §Shape skips); whisper's decode ctx is architecture-bounded.
LONG_OK = {"jamba-1.5-large-398b", "xlstm-125m", "gemma3-27b"}

TP = 16  # model-axis degree on both meshes


def runnable_pairs():
    pairs = []
    for arch in ALIASES:
        for shape in SHAPES:
            if shape == "long_500k" and arch not in LONG_OK:
                continue
            pairs.append((arch, shape))
    return pairs


# ---------------------------------------------------------------------------
def batch_specs(cfg, kind: str, seq: int, batch: int):
    """ShapeDtypeStructs + logical axes for every model input."""
    i32 = jnp.int32
    specs, axes = {}, {}
    if kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((batch, seq), i32)
        specs["labels"] = jax.ShapeDtypeStruct((batch, seq), i32)
        axes["tokens"] = ("batch", "seq")
        axes["labels"] = ("batch", "seq")
        if cfg.arch_type == "vlm":
            specs["mm_embeds"] = jax.ShapeDtypeStruct(
                (batch, cfg.mm_tokens, cfg.d_model), cfg.dtype)
            axes["mm_embeds"] = ("batch", None, "embed_act")
            specs["positions"] = jax.ShapeDtypeStruct((batch, seq, 3), i32)
            axes["positions"] = ("batch", "seq", None)
        if cfg.is_encoder_decoder:
            specs["enc_frames"] = jax.ShapeDtypeStruct(
                (batch, cfg.encoder_seq, cfg.d_model), cfg.dtype)
            axes["enc_frames"] = ("batch", None, "embed_act")
    elif kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((batch, seq), i32)
        axes["tokens"] = ("batch", "seq")
        if cfg.arch_type == "vlm":
            specs["mm_embeds"] = jax.ShapeDtypeStruct(
                (batch, cfg.mm_tokens, cfg.d_model), cfg.dtype)
            axes["mm_embeds"] = ("batch", None, "embed_act")
            specs["positions"] = jax.ShapeDtypeStruct((batch, seq, 3), i32)
            axes["positions"] = ("batch", "seq", None)
        if cfg.is_encoder_decoder:
            specs["enc_frames"] = jax.ShapeDtypeStruct(
                (batch, cfg.encoder_seq, cfg.d_model), cfg.dtype)
            axes["enc_frames"] = ("batch", None, "embed_act")
    else:  # decode: ONE new token against a seq-length KV cache
        specs["tokens"] = jax.ShapeDtypeStruct((batch, 1), i32)
        axes["tokens"] = ("batch", "seq")
        pos_shape = (batch, 1, 3) if cfg.arch_type == "vlm" else (batch, 1)
        specs["positions"] = jax.ShapeDtypeStruct(pos_shape, i32)
        axes["positions"] = ("batch", "seq", None)[: len(pos_shape)]
    return specs, axes


def input_specs(arch: str, shape: str, opts: frozenset = frozenset()):
    """Public helper: (cfg, step_fn, abstract args, shardings builder)."""
    cfg = get_config(arch)
    if "seq_par_repl" not in opts:
        # heads/vocab padding is only needed when those dims are TP-sharded
        cfg = pad_for_tp(cfg, TP)
    meta = SHAPES[shape]
    specs, axes = batch_specs(cfg, meta["kind"], meta["seq"], meta["batch"])
    return cfg, meta, specs, axes


# ---------------------------------------------------------------------------
def build(arch: str, shape: str, mesh, rules, opts=frozenset()):
    cfg, meta, specs, axes = input_specs(arch, shape, opts)
    kind = meta["kind"]
    kv_dtype = jnp.int8 if "kv_int8" in opts else jnp.bfloat16

    def shard(ax):
        from repro.models.params import logical_to_pspec
        return NamedSharding(mesh, logical_to_pspec(tuple(ax), rules))

    batch_shardings = {k: shard(axes[k]) for k in specs}

    if kind == "train":
        state = abstract_train_state(cfg)
        decls = T.model_decls(cfg)
        p_specs = param_pspecs(decls, rules)
        p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                               is_leaf=lambda x: isinstance(x, PartitionSpec))
        opt_shard = AdamWState(NamedSharding(mesh, PartitionSpec()),
                               p_shard, p_shard)
        state_shard = type(state)(p_shard, opt_shard)

        def fn(st, batch):
            return train_step(st, batch, cfg, remat=True)

        jitted = jax.jit(fn, in_shardings=(state_shard, batch_shardings),
                         donate_argnums=(0,))
        args = (state, specs)
    else:
        decls = T.model_decls(cfg)
        params = abstract_params(decls)
        p_specs = param_pspecs(decls, rules)
        p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                               is_leaf=lambda x: isinstance(x, PartitionSpec))
        cache_len = meta["seq"]
        cdecls = T.cache_decls(cfg, meta["batch"], cache_len, dtype=kv_dtype,
                               window_cache="window_cache" in opts)
        cache = abstract_params(cdecls)
        c_specs = param_pspecs(cdecls, rules)
        c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs,
                               is_leaf=lambda x: isinstance(x, PartitionSpec))

        if kind == "prefill":
            def fn(p, cache, batch):
                toks = batch["tokens"]
                logits, new_cache, _ = T.forward(
                    p, cfg, toks, positions=batch.get("positions"),
                    mm_embeds=batch.get("mm_embeds"),
                    enc_frames=batch.get("enc_frames"), cache=cache,
                    q_start=0, last_only=True)
                return logits, new_cache
        else:
            def fn(p, cache, batch):
                logits, new_cache, _ = T.forward(
                    p, cfg, batch["tokens"], positions=batch["positions"],
                    cache=cache)
                return logits, new_cache

        jitted = jax.jit(fn, in_shardings=(p_shard, c_shard, batch_shardings),
                         donate_argnums=(1,))
        args = (params, cache, specs)
    return cfg, jitted, args


# ---------------------------------------------------------------------------
DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4,
               "u64": 8, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^\s*(ENTRY\s+)?%([\w.\-]+)\s*\(.*\{\s*$")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(shapes: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shapes):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Collective op bytes, trip-count aware.

    Collectives inside ``lax.scan``-generated While bodies appear once in the
    HLO text but execute trip-count times; we parse computations, find each
    while's body + the loop bound (max integer constant in its condition
    region), and multiply through (recursively for nested scans, e.g. remat).
    """
    # split into computations
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
        elif cur is not None:
            comps[cur].append(line)

    # per-computation: collectives and (body, condition) pairs
    coll_of: dict[str, list[tuple[str, int]]] = {}
    whiles_of: dict[str, list[tuple[str, str]]] = {}
    for name, lines in comps.items():
        colls, whiles = [], []
        for line in lines:
            cm = _COLL_RE.search(line)
            if cm:
                colls.append((cm.group(2), _shape_bytes(cm.group(1))))
            wm = _WHILE_RE.search(line)
            if wm:
                whiles.append((wm.group(1), wm.group(2)))
        coll_of[name] = colls
        whiles_of[name] = whiles

    def trip_count(cond: str) -> int:
        consts = [int(c) for line in comps.get(cond, ())
                  for c in _CONST_RE.findall(line)]
        return max(consts, default=1) or 1

    out: dict[str, dict] = {}

    def walk(comp: str, mult: int, seen: tuple):
        if comp in seen or comp not in comps:
            return
        for op, nbytes in coll_of.get(comp, ()):
            rec = out.setdefault(op, {"count": 0, "bytes": 0})
            rec["count"] += mult
            rec["bytes"] += nbytes * mult
        for cond, body in whiles_of.get(comp, ()):
            walk(body, mult * trip_count(cond), seen + (comp,))

    if entry is not None:
        walk(entry, 1, ())
    else:  # fallback: flat scan, no trip scaling
        for name in comps:
            walk(name, 1, (object(),))
    return out


def run_one(arch: str, shape: str, mesh_kind: str, outdir: str,
            opts: frozenset = frozenset()) -> dict:
    multi = mesh_kind == "multi"
    meta = SHAPES[shape]
    mode = ("train" if meta["kind"] == "train"
            else ("long_ctx" if meta.get("long") else "serve"))
    mesh = make_production_mesh(multi_pod=multi)
    rules = make_rules(mode, multi_pod=multi, opts=opts)

    t0 = time.time()
    with mesh:
        with use_rules(rules, mesh):
            cfg, jitted, args = build(arch, shape, mesh, rules, opts)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            coll = collective_bytes(compiled.as_text())

    n_dev = mesh.devices.size
    from repro.launch.analysis import analytic_costs, roofline_terms
    costs = analytic_costs(cfg, meta["kind"], meta["seq"], meta["batch"],
                           kv_dtype_bytes=1 if "kv_int8" in opts else 2,
                           window_cache="window_cache" in opts)
    coll_total = sum(v["bytes"] for v in coll.values())
    terms = roofline_terms(costs, coll_total, n_dev)
    result = {
        "arch": arch, "shape": shape, "mesh": mesh_kind, "devices": int(n_dev),
        "mode": mode, "opts": sorted(opts),
        "params": param_count(T.model_decls(cfg)),
        "padded_heads": cfg.num_heads, "orig_heads": cfg.orig_num_heads or cfg.num_heads,
        "padded_kv": cfg.num_kv_heads, "orig_kv": cfg.orig_num_kv_heads or cfg.num_kv_heads,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": float(cost.get("flops", -1)),
        "bytes_accessed": float(cost.get("bytes accessed", -1)),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "collectives": coll,
        "analytic": {
            "flops_global": costs.flops,
            "hbm_bytes_global": costs.hbm_bytes,
            "model_flops": costs.model_flops,
            "kv_cache_bytes_global": costs.kv_cache_bytes,
        },
        "roofline": terms,
    }
    os.makedirs(outdir, exist_ok=True)
    tag = ("__" + "+".join(sorted(opts))) if opts else ""
    path = os.path.join(outdir, f"{arch}__{shape}__{mesh_kind}{tag}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    print(f"[dryrun] {arch} x {shape} x {mesh_kind}{tag}: "
          f"compile={t_compile:.1f}s flops={result['flops']:.3e} "
          f"colls={ {k: v['count'] for k, v in coll.items()} }")
    print(f"  memory: { {k: v for k, v in result['memory'].items()} }")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--opts", default="",
                    help="comma list: moe_data,seq_par,act_model,kv_int8,window_cache")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    if args.list:
        for a, s in runnable_pairs():
            print(a, s)
        return
    opts = frozenset(o for o in args.opts.split(",") if o)
    run_one(args.arch, args.shape, args.mesh, args.out, opts)


if __name__ == "__main__":
    main()
