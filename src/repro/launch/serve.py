"""Serving launcher: run the TCM-Serve engine on a workload.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-vl-2b \
      --policy tcm --mix MH --rate 2.0 --num-requests 200 --executor sim

Executors:
  sim  — cost model derived from the FULL assigned architecture (A100-class
         coefficients); workload-scale scheduler experiments.
  real — the actual reduced JAX model on CPU (proves the engine end to end).
"""
from __future__ import annotations

import argparse

from repro.configs import get_config, get_reduced
from repro.core.classifier import NaiveClassifier, SmartClassifier
from repro.core.estimator import ImpactEstimator
from repro.core.profiler import WorkloadProfiler
from repro.core.scheduler import make_policy
from repro.serving.engine import Engine, EngineConfig
from repro.serving.executors import ExecutorConfig, ModelExecutor, \
    SimExecutor, cost_model_for_arch, make_cost_model
from repro.serving.metrics import fmt_table, goodput, summarize
from repro.serving.workload import WorkloadConfig, generate, \
    profiling_workload


def build_stack(arch: str, executor_kind: str = "sim", *,
                naive_classifier: bool = False, model_preset: str | None = None,
                kv_pages: int | None = None, token_budget: int = 512,
                slo_scale: float = 5.0):
    """(engine-factory, executor, classifier) for one model."""
    if executor_kind == "sim":
        cm = (make_cost_model(model_preset) if model_preset
              else cost_model_for_arch(get_config(arch)))
        executor = SimExecutor(cm)
        prof_reqs = profiling_workload()
    else:
        # "real" = batched paged path; "real-legacy" = the seed's
        # sequential dense-slot oracle (token-parity baseline). An
        # explicit kv_pages sizes the executor's paged stores directly —
        # KV capacity decoupled from the max_slots x max_len slot
        # geometry (prefix-cache-heavy configs want far more resident
        # KV than the running set's context windows).
        exec_cfg = ExecutorConfig(
            max_slots=16, max_len=256,
            legacy=(executor_kind == "real-legacy"),
            num_pages=kv_pages).resolved()
        executor = ModelExecutor(get_reduced(arch), exec_cfg)
        prof_reqs = profiling_workload(n_per_modality=8)
        # real mode: the engine's KV capacity IS the resolved executor
        # capacity — one derivation (ExecutorConfig.resolved), so the
        # admission path and the paged stores agree by construction (the
        # default A100-sized kv_pages would build gigabyte page arrays)
        kv_pages = exec_cfg.num_pages
    profile = WorkloadProfiler(executor, arch).build(prof_reqs)
    est = ImpactEstimator.train(profile)
    classifier = (NaiveClassifier(est) if naive_classifier
                  else SmartClassifier.train(est, profile))
    cfg_kwargs = dict(token_budget=token_budget, slo_scale=slo_scale)
    if kv_pages is not None:
        cfg_kwargs["kv_pages"] = kv_pages
    engine_cfg = EngineConfig(**cfg_kwargs)
    return executor, classifier, engine_cfg, profile, est


def serve(arch: str, policy: str, workload: WorkloadConfig, *,
          executor_kind: str = "sim", naive_classifier: bool = False,
          model_preset: str | None = None, kv_pages: int | None = None,
          token_budget: int = 512, slo_scale: float = 5.0):
    executor, classifier, engine_cfg, _, _ = build_stack(
        arch, executor_kind, naive_classifier=naive_classifier,
        model_preset=model_preset, kv_pages=kv_pages,
        token_budget=token_budget, slo_scale=slo_scale)
    engine = Engine(make_policy(policy), executor, classifier, engine_cfg)
    reqs = generate(workload)
    done = engine.run(reqs)
    return done, engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-vl-2b")
    ap.add_argument("--policy", default="tcm",
                    choices=["fcfs", "edf", "static", "naive-aging", "tcm"])
    ap.add_argument("--mix", default="MH", choices=["T0", "ML", "MH"])
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--num-requests", type=int, default=200)
    ap.add_argument("--executor", default="sim",
                    choices=["sim", "real", "real-legacy"])
    ap.add_argument("--naive-classifier", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    wl = WorkloadConfig(mix=args.mix, rate=args.rate,
                        num_requests=args.num_requests, seed=args.seed)
    done, engine = serve(args.arch, args.policy, wl,
                         executor_kind=args.executor,
                         naive_classifier=args.naive_classifier)
    s = summarize(done)
    print(fmt_table(s, f"{args.arch} | {args.policy} | {args.mix} "
                       f"@ {args.rate} rps ({args.executor})"))
    print(f"goodput: {goodput(done):.3f} req/s   engine iterations: "
          f"{engine.iterations}   simulated time: {engine.now:.1f}s")


if __name__ == "__main__":
    main()
