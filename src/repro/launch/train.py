"""Training launcher.

CPU (default): trains a reduced/~100M-scale config for a few hundred steps
with the synthetic packed-token pipeline + checkpointing.

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
      --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.train.checkpoint import load, save
from repro.train.data import PackedTokenDataset
from repro.train.loop import make_train_state, train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="use the full assigned config (TPU-scale)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    # xlstm-125m IS ~100M-scale and CPU-trainable at short seq as-is
    if args.arch == "xlstm-125m" and not args.full:
        cfg = dataclasses.replace(get_config(args.arch), max_seq_len=args.seq)

    state = make_train_state(cfg, jax.random.PRNGKey(0))
    if args.resume:
        state = load(args.resume, state)
    n = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={n/1e6:.1f}M layers={cfg.num_layers} "
          f"d_model={cfg.d_model}")

    data = PackedTokenDataset(cfg.vocab_size, args.seq)
    step_fn = jax.jit(lambda s, b: train_step(s, b, cfg, base_lr=args.lr))

    t0 = time.time()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in
                 data.batch(step, args.batch).items()}
        state, metrics = step_fn(state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)")
    if args.ckpt:
        save(args.ckpt, state)
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
