"""Analytic FLOPs / HBM-bytes model for the roofline analysis.

Why analytic: XLA:CPU's HloCostAnalysis is *inconsistently* trip-count-aware
for While loops (verified: a plain scan reports 1x body FLOPs, while some
optimized loops report full-trip FLOPs — see EXPERIMENTS.md §Dry-run notes).
Since every layer's einsum inventory is ours, we count compiled FLOPs
exactly (incl. remat recompute, TP head padding, MoE capacity + dispatch
overhead) and use HLO text only for collective bytes (trip-aware walker in
dryrun.py).

All numbers are GLOBAL (whole cluster, one step); divide by chip count for
per-device terms.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.models.config import (ATTN, ATTN_L, ATTN_MOE, DEC_ATTN,
                                 MAMBA, MAMBA_MOE, MLSTM, MOE_BLOCKS, SLSTM,
                                 ModelConfig)
from repro.models.params import param_count
from repro.models.transformer import model_decls

BF16 = 2
F32 = 4


def _attn_layer_flops(cfg: ModelConfig, tokens: int, ctx: int, window: int,
                      cross_tokens: int = 0) -> float:
    """Forward FLOPs for one attention layer over `tokens` new tokens with
    average attended context `ctx` (already window-clamped by caller)."""
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    qkv = 2 * tokens * D * (H + 2 * KV) * hd
    attn = 4 * tokens * ctx * H * hd            # scores + weighted sum
    out = 2 * tokens * H * hd * D
    cross = 0.0
    if cross_tokens:
        cross = 2 * tokens * D * H * hd * 2 + 4 * tokens * cross_tokens * H * hd
    return qkv + attn + out + cross


def _mlp_flops(cfg: ModelConfig, tokens: int) -> float:
    mats = 2 if cfg.is_encoder_decoder else 3   # gelu-mlp vs swiglu
    return 2 * tokens * cfg.d_model * cfg.d_ff * mats


def _moe_flops(cfg: ModelConfig, tokens: int, group: int = 512) -> float:
    D, F, E, K = cfg.d_model, cfg.d_ff, cfg.num_experts, cfg.experts_per_token
    cf = cfg.capacity_factor
    router = 2 * tokens * D * E
    # capacity per group C = G*K*cf/E; expert matmuls over E*C slots
    expert = 2 * tokens * K * cf * D * F * 3
    dispatch = 2 * 2 * tokens * K * cf * E * D  # dispatch + combine einsums
    return router + expert + dispatch


def _mamba_flops(cfg: ModelConfig, tokens: int) -> float:
    D, DI, N = cfg.d_model, cfg.d_inner, cfg.mamba_d_state
    R = max(16, -(-D // 16))
    proj = 2 * tokens * D * 2 * DI + 2 * tokens * DI * D
    conv = 2 * tokens * cfg.mamba_d_conv * DI
    dt = 2 * tokens * DI * R * 2
    bc = 2 * tokens * DI * N * 2
    scan = 10 * tokens * DI * N                 # elementwise recurrence
    return proj + conv + dt + bc + scan


def _mlstm_flops(cfg: ModelConfig, tokens: int) -> float:
    D = cfg.d_model
    DI = int(cfg.xlstm_proj_factor * D)
    hd = DI // cfg.num_heads
    proj = 2 * tokens * D * 2 * DI + 2 * tokens * DI * D
    qkv = 3 * 2 * tokens * DI * DI
    rec = 8 * tokens * DI * hd                  # C update + readout per head
    return proj + qkv + rec


def _slstm_flops(cfg: ModelConfig, tokens: int) -> float:
    D = cfg.d_model
    hd = D // cfg.num_heads
    gates = 8 * 2 * tokens * D * hd             # 4 input + 4 recurrent blocks
    ffn = 2 * tokens * D * int(4 * D / 3) * 3
    return gates + ffn


@dataclass
class Costs:
    flops: float          # compiled-equivalent global FLOPs (one step)
    hbm_bytes: float      # global HBM traffic (one step)
    model_flops: float    # 6*N*D (train) / 2*N*D (inference), N_active for MoE
    kv_cache_bytes: float


def _active_params(cfg: ModelConfig) -> float:
    """Parameter count with MoE experts scaled to experts_per_token."""
    total = param_count(model_decls(cfg))
    if cfg.num_experts == 0:
        return float(total)
    # subtract inactive expert weights
    moe_layers = sum(1 for i in range(cfg.num_layers) if cfg.is_moe_layer(i))
    expert_params = 3 * cfg.d_model * cfg.d_ff
    inactive = moe_layers * (cfg.num_experts - cfg.experts_per_token) * expert_params
    return float(total - inactive)


def analytic_costs(cfg: ModelConfig, kind: str, seq: int, batch: int,
                   *, remat: bool = True, kv_dtype_bytes: int = BF16,
                   window_cache: bool = False) -> Costs:
    """kind: train | prefill | decode."""
    n_params = param_count(model_decls(cfg))
    n_active = _active_params(cfg)

    if kind == "train":
        tokens_new, ctx_avg, dec_tokens = batch * seq, seq / 2, batch * seq
    elif kind == "prefill":
        tokens_new, ctx_avg, dec_tokens = batch * seq, seq / 2, batch * seq
    else:  # decode: one token against a seq-length cache
        tokens_new, ctx_avg, dec_tokens = batch * 1, seq, batch * 1

    fwd = 0.0
    kv_bytes = 0.0
    for i in range(cfg.num_layers):
        bt = cfg.block_type(i)
        if bt in (ATTN, ATTN_L, ATTN_MOE, DEC_ATTN):
            w = cfg.window_for(bt)
            ctx = min(ctx_avg, w) if w else ctx_avg
            cross = cfg.encoder_seq if bt == DEC_ATTN else 0
            fwd += _attn_layer_flops(cfg, tokens_new, ctx, w, cross)
            cache_len = min(seq, w) if (w and window_cache) else seq
            kv_bytes += 2 * batch * cache_len * cfg.num_kv_heads * cfg.hd \
                * kv_dtype_bytes
        elif bt in (MAMBA, MAMBA_MOE):
            fwd += _mamba_flops(cfg, tokens_new)
            kv_bytes += batch * cfg.d_inner * cfg.mamba_d_state * F32
        elif bt == MLSTM:
            fwd += _mlstm_flops(cfg, tokens_new)
            DI = int(cfg.xlstm_proj_factor * cfg.d_model)
            hd = DI // cfg.num_heads
            kv_bytes += batch * cfg.num_heads * hd * hd * F32
        elif bt == SLSTM:
            fwd += _slstm_flops(cfg, tokens_new)
            kv_bytes += 4 * batch * cfg.d_model * F32
        if bt in MOE_BLOCKS:
            fwd += _moe_flops(cfg, tokens_new)
        elif bt not in (MLSTM, SLSTM):
            fwd += _mlp_flops(cfg, tokens_new)
    # encoder (whisper): runs once per sequence in train/prefill
    if cfg.is_encoder_decoder and kind != "decode":
        enc_t = batch * cfg.encoder_seq
        for _ in range(cfg.num_encoder_layers):
            fwd += _attn_layer_flops(cfg, enc_t, cfg.encoder_seq / 2, 0)
            fwd += _mlp_flops(cfg, enc_t)
    # lm head
    head_tokens = batch if kind == "prefill" else tokens_new  # last_only
    fwd += 2 * head_tokens * cfg.d_model * cfg.vocab_size

    params_bytes = n_params * BF16
    act_stream = tokens_new * cfg.d_model * BF16 * cfg.num_layers

    if kind == "train":
        flops = fwd * (4.0 if remat else 3.0)   # fwd + 2x bwd (+1x remat)
        # fwd reads params; bwd reads params; optimizer reads/writes p,m,v f32
        hbm = params_bytes * 2 + n_params * F32 * 6 + act_stream * 6
        model_flops = 6.0 * n_active * dec_tokens
    elif kind == "prefill":
        flops = fwd
        hbm = params_bytes + kv_bytes + act_stream * 4
        model_flops = 2.0 * n_active * dec_tokens
    else:
        flops = fwd
        # decode is bandwidth-bound: weights + full KV/state read + write
        hbm = params_bytes + kv_bytes + act_stream * 4
        model_flops = 2.0 * n_active * dec_tokens
    return Costs(flops, hbm, model_flops, kv_bytes)


# Hardware constants (TPU v5e, per chip)
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s per link


def roofline_terms(costs: Costs, coll_bytes_per_dev: float, chips: int) -> dict:
    compute_s = costs.flops / (chips * PEAK_FLOPS)
    memory_s = costs.hbm_bytes / (chips * HBM_BW)
    collective_s = coll_bytes_per_dev / ICI_BW
    dominant = max(
        [("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)], key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "useful_flops_ratio": costs.model_flops / max(costs.flops, 1.0),
    }
