import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Sweep every runnable (arch x shape x mesh) dry-run in ONE process
(device count is fixed by the env var above). Skips pairs whose JSON
already exists, so the sweep is resumable.

  PYTHONPATH=src python -m repro.launch.dryrun_all [--mesh single|multi|both]
"""
import argparse
import json
import time
import traceback

from repro.launch.dryrun import run_one, runnable_pairs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--only-arch", default=None)
    args = ap.parse_args()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for mesh in meshes:
        for arch, shape in runnable_pairs():
            if args.only_arch and arch != args.only_arch:
                continue
            path = os.path.join(args.out, f"{arch}__{shape}__{mesh}.json")
            if os.path.exists(path):
                continue
            t0 = time.time()
            try:
                run_one(arch, shape, mesh, args.out)
            except Exception as e:  # record and continue
                failures.append((arch, shape, mesh, repr(e)))
                print(f"[FAIL] {arch} x {shape} x {mesh}: {e}")
                traceback.print_exc()
            print(f"  ({time.time()-t0:.0f}s)", flush=True)
    if failures:
        with open(os.path.join(args.out, "FAILURES.json"), "w") as f:
            json.dump(failures, f, indent=1)
        print(f"{len(failures)} failures")
    else:
        print("ALL PASS")


if __name__ == "__main__":
    main()
