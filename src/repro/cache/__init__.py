"""Engine-side KV memory subsystem: page accounting (allocator) and the
JAX-side paged store (paged — imported directly to avoid pulling jax into
scheduler-only code paths)."""
from .allocator import (BlockAllocator, OutOfPages, PrefixMatch,
                        common_prefix_tokens, iter_page_runs)

__all__ = ["BlockAllocator", "OutOfPages", "PrefixMatch",
           "common_prefix_tokens", "iter_page_runs"]
