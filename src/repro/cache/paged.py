"""Paged KV store: JAX-side page arrays + write/read ops per layer stack.

Layout per layer: (num_pages, page_size, KV, hd), matching the Pallas
paged-attention kernel. Writes are block-table scatters; the whole store is
functionally updated (donated in jit on real deployments).

``PagedKVStore`` is the single-layer view (engine bookkeeping, kernel
tests).  The serving executor's batched path holds one ``PagedStackStore``
per scan stage instead: the same page arrays with a leading ``layers`` dim
so the transformer's ``lax.scan`` over stacked layer weights can consume
the KV pages as scan xs/ys (DESIGN.md §Batched execution path).  Batched
multi-sequence writes go through ``scatter_pages`` — one block-table
scatter for every (sequence, token) pair in the step, with ragged rows
routed to a trash page.

SSM/xLSTM state caches have *constant* per-request footprint, so they use a
slot store (one row per active request) rather than pages — the classifier
sees this as a constant memory feature (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


def scatter_pages(k_pages, v_pages, k_new, v_new, block_table, start,
                  new_lens, trash_page):
    """Scatter S new tokens for each of B sequences into shared page arrays.

    k_new/v_new: (B, S, KV, hd) — per-sequence new tokens, right-padded;
    block_table: (B, max_pages) int32 page ids per sequence;
    start: (B,) int32 context length already written per sequence;
    new_lens: (B,) int32 valid tokens per row (<= S) — padding tokens and
    whole padding rows are routed to ``trash_page`` so one fused scatter
    covers the ragged batch;
    trash_page: page id reserved for discarded writes (never mapped).

    Returns (k_pages, v_pages) functionally updated.
    """
    B, S = k_new.shape[:2]
    page = k_pages.shape[1]
    max_tokens = block_table.shape[1] * page
    pos = start[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]   # (B,S)
    valid = jnp.arange(S, dtype=jnp.int32)[None, :] < new_lens[:, None]
    posc = jnp.minimum(pos, max_tokens - 1)  # clamp before table lookup
    pids = jnp.take_along_axis(block_table, posc // page, axis=1)
    pids = jnp.where(valid, pids, trash_page)
    offs = posc % page
    flat = lambda a: a.reshape(B * S, *a.shape[2:])  # noqa: E731
    k_pages = k_pages.at[flat(pids), flat(offs)].set(
        flat(k_new).astype(k_pages.dtype), mode="drop")
    v_pages = v_pages.at[flat(pids), flat(offs)].set(
        flat(v_new).astype(v_pages.dtype), mode="drop")
    return k_pages, v_pages


@dataclass
class PagedKVStore:
    """One layer's paged KV arrays; engine holds one per attention layer."""
    k_pages: jax.Array  # (P, page, KV, hd)
    v_pages: jax.Array

    @classmethod
    def create(cls, num_pages, page_size, kv_heads, head_dim,
               dtype=jnp.bfloat16):
        shape = (num_pages, page_size, kv_heads, head_dim)
        return cls(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))

    @property
    def page_size(self):
        return self.k_pages.shape[1]

    def write(self, k_new, v_new, page_ids, start: int):
        """Write S new tokens for ONE request.

        k_new/v_new: (S, KV, hd); page_ids: (n,) python/int32 array of the
        request's pages; start: the request's context length before this
        write. Returns updated store.
        """
        S = k_new.shape[0]
        page = self.page_size
        pos = start + jnp.arange(S)
        pids = jnp.asarray(page_ids)[pos // page]
        offs = pos % page
        k_pages = self.k_pages.at[pids, offs].set(
            k_new.astype(self.k_pages.dtype))
        v_pages = self.v_pages.at[pids, offs].set(
            v_new.astype(self.v_pages.dtype))
        return PagedKVStore(k_pages, v_pages)

    def write_batch(self, k_new, v_new, block_table, start, new_lens,
                    trash_page):
        """Batched multi-sequence scatter (see ``scatter_pages``)."""
        k_pages, v_pages = scatter_pages(
            self.k_pages, self.v_pages, k_new, v_new, block_table, start,
            new_lens, trash_page)
        return PagedKVStore(k_pages, v_pages)

    def gather(self, page_ids):
        """(n_pages,) -> contiguous (n_pages*page, KV, hd) k, v."""
        pids = jnp.asarray(page_ids)
        k = self.k_pages[pids].reshape(-1, *self.k_pages.shape[2:])
        v = self.v_pages[pids].reshape(-1, *self.v_pages.shape[2:])
        return k, v


jax.tree_util.register_pytree_node(
    PagedKVStore,
    lambda s: ((s.k_pages, s.v_pages), None),
    lambda _, c: PagedKVStore(*c),
)


@dataclass
class PagedStackStore:
    """Paged KV for one *stack* of layers: (layers, P, page, KV, hd).

    One per attention block position per scan stage.  Registered as a
    pytree so ``jax.lax.scan`` over the stacked layer weights can slice the
    leading ``layers`` axis of both leaves and hand each scan step a
    per-layer ``PagedStackStore`` view (leaves then (P, page, KV, hd));
    the updated pages come back out as scan ys with the layer dim
    restacked.  The whole container is donated under jit so XLA updates
    the page arrays in place across iterations.
    """
    k_pages: jax.Array
    v_pages: jax.Array

    @classmethod
    def create(cls, layers: int, num_pages: int, page_size: int,
               kv_heads: int, head_dim: int, dtype=jnp.bfloat16):
        shape = (layers, num_pages, page_size, kv_heads, head_dim)
        return cls(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))

    @property
    def page_size(self):
        return self.k_pages.shape[-3]

    def write_batch(self, k_new, v_new, block_table, start, new_lens,
                    trash_page):
        """Per-layer view write (leaves must be layer slices, ndim 4)."""
        k_pages, v_pages = scatter_pages(
            self.k_pages, self.v_pages, k_new, v_new, block_table, start,
            new_lens, trash_page)
        return PagedStackStore(k_pages, v_pages)

    def copy_page(self, src, dst) -> "PagedStackStore":
        """Copy one page's K/V across every layer of the stack — the
        prefix cache's copy-on-write boundary-page copy (src stays a
        valid cached page; dst becomes the claimer's private copy).
        ``src``/``dst`` may be traced scalars, so one jit signature
        serves every copy."""
        def cp(a):
            page = jax.lax.dynamic_index_in_dim(a, src, axis=1,
                                                keepdims=True)
            return jax.lax.dynamic_update_slice_in_dim(a, page, dst,
                                                       axis=1)
        return PagedStackStore(cp(self.k_pages), cp(self.v_pages))

    def gather_batch(self, block_table):
        """Per-layer view: (B, maxp) -> contiguous (B, maxp*page, KV, hd)."""
        B, maxp = block_table.shape
        k = self.k_pages[block_table].reshape(
            B, -1, *self.k_pages.shape[-2:])
        v = self.v_pages[block_table].reshape(
            B, -1, *self.v_pages.shape[-2:])
        return k, v


jax.tree_util.register_pytree_node(
    PagedStackStore,
    lambda s: ((s.k_pages, s.v_pages), None),
    lambda _, c: PagedStackStore(*c),
)


@dataclass
class SlotStore:
    """Constant-size per-request state (SSM/xLSTM/conv): one slot per row."""
    data: dict  # name -> (slots, ...) arrays

    @classmethod
    def create(cls, num_slots: int, shapes: dict, dtypes: dict | None = None):
        dtypes = dtypes or {}
        return cls({name: jnp.zeros((num_slots,) + tuple(shape),
                                    dtypes.get(name, jnp.float32))
                    for name, shape in shapes.items()})

    def read(self, slot: int):
        return {k: v[slot] for k, v in self.data.items()}

    def write(self, slot: int, values: dict):
        return SlotStore({k: self.data[k].at[slot].set(values[k])
                          for k in self.data})


jax.tree_util.register_pytree_node(
    SlotStore,
    lambda s: (tuple(s.data.values()), tuple(s.data.keys())),
    lambda keys, vals: SlotStore(dict(zip(keys, vals))),
)
