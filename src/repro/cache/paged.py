"""Paged KV store: JAX-side page arrays + write/read ops per layer stack.

Layout per layer: (num_pages, page_size, KV, hd), matching the Pallas
paged-attention kernel. Writes are block-table scatters; the whole store is
functionally updated (donated in jit on real deployments).

SSM/xLSTM state caches have *constant* per-request footprint, so they use a
slot store (one row per active request) rather than pages — the classifier
sees this as a constant memory feature (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass
class PagedKVStore:
    """One layer's paged KV arrays; engine holds one per attention layer."""
    k_pages: jax.Array  # (P, page, KV, hd)
    v_pages: jax.Array

    @classmethod
    def create(cls, num_pages, page_size, kv_heads, head_dim,
               dtype=jnp.bfloat16):
        shape = (num_pages, page_size, kv_heads, head_dim)
        return cls(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))

    @property
    def page_size(self):
        return self.k_pages.shape[1]

    def write(self, k_new, v_new, page_ids, start: int):
        """Write S new tokens for ONE request.

        k_new/v_new: (S, KV, hd); page_ids: (n,) python/int32 array of the
        request's pages; start: the request's context length before this
        write. Returns updated store.
        """
        S = k_new.shape[0]
        page = self.page_size
        pos = start + jnp.arange(S)
        pids = jnp.asarray(page_ids)[pos // page]
        offs = pos % page
        k_pages = self.k_pages.at[pids, offs].set(
            k_new.astype(self.k_pages.dtype))
        v_pages = self.v_pages.at[pids, offs].set(
            v_new.astype(self.v_pages.dtype))
        return PagedKVStore(k_pages, v_pages)

    def gather(self, page_ids):
        """(n_pages,) -> contiguous (n_pages*page, KV, hd) k, v."""
        pids = jnp.asarray(page_ids)
        k = self.k_pages[pids].reshape(-1, *self.k_pages.shape[2:])
        v = self.v_pages[pids].reshape(-1, *self.v_pages.shape[2:])
        return k, v


jax.tree_util.register_pytree_node(
    PagedKVStore,
    lambda s: ((s.k_pages, s.v_pages), None),
    lambda _, c: PagedKVStore(*c),
)


@dataclass
class SlotStore:
    """Constant-size per-request state (SSM/xLSTM/conv): one slot per row."""
    data: dict  # name -> (slots, ...) arrays

    @classmethod
    def create(cls, num_slots: int, shapes: dict, dtypes: dict | None = None):
        dtypes = dtypes or {}
        return cls({name: jnp.zeros((num_slots,) + tuple(shape),
                                    dtypes.get(name, jnp.float32))
                    for name, shape in shapes.items()})

    def read(self, slot: int):
        return {k: v[slot] for k, v in self.data.items()}

    def write(self, slot: int, values: dict):
        return SlotStore({k: self.data[k].at[slot].set(values[k])
                          for k in self.data})


jax.tree_util.register_pytree_node(
    SlotStore,
    lambda s: (tuple(s.data.values()), tuple(s.data.keys())),
    lambda keys, vals: SlotStore(dict(zip(keys, vals))),
)
