"""Paged KV store: JAX-side page arrays + write/read ops per layer stack.

``PagedKVStore`` is the single-layer view (engine bookkeeping, kernel
tests): (num_pages, page_size, KV, hd) arrays matching the Pallas
paged-attention kernel, functionally updated by block-table scatters.

``PagedStackStore`` is the serving executor's batched container — the
paged KV of one *stack* of layers (one scan stage's block position),
flattened so the whole store rides through the transformer's
``jax.lax.scan`` as **carry**: leaves are
``(layers * pages_per_layer, page, KV, hd)`` and layer ``l``'s page ``p``
lives at row ``l * pages_per_layer + p``.  The scan's per-step layer
index offsets reads/writes into the flat pool, so a batched step touches
only resident pages — donated under jit, XLA aliases the carry in place
and step time is independent of store *capacity* (DESIGN.md §Ragged
paged execution).  Batched multi-sequence writes go through
``scatter_pages`` — one block-table scatter for every (sequence, token)
pair in the step, with ragged padding routed to the layer's trash page.

The **container dtype** is backend-dependent (``store_dtype()``): bf16
natively on TPU; f32 on CPU, where XLA lowers bf16 scatters through
whole-array f32 convert round-trips (an O(capacity) cost that would
defeat the carry layout).  Stored *values* are always rounded through
bf16 first, so the numbers a reader gets back are bit-identical either
way and emitted-token parity against the bf16 legacy cache holds exactly.

SSM/xLSTM state caches have *constant* per-request footprint, so they use
a slot store (one row per active request) rather than pages — the
classifier sees this as a constant memory feature (see DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp


def store_dtype():
    """Container dtype for paged stack stores on this backend.

    TPU scatters bf16 natively; XLA:CPU expands a bf16 scatter into a
    loop over f32 *copies of the whole array* (one convert each way per
    update), making every store write O(capacity).  An f32 container
    keeps the scatter in place on CPU; values are bf16-rounded before
    storing either way (f32 represents every bf16 exactly), so readers
    see identical bits on both backends.
    """
    return jnp.bfloat16 if jax.default_backend() == "tpu" else jnp.float32


def scatter_pages(k_pages, v_pages, k_new, v_new, block_table, start,
                  new_lens, trash_page, base=0):
    """Scatter S new tokens for each of B sequences into shared page arrays.

    k_new/v_new: (B, S, KV, hd) — per-sequence new tokens, right-padded;
    block_table: (B, max_pages) int32 page ids per sequence;
    start: (B,) int32 context length already written per sequence;
    new_lens: (B,) int32 valid tokens per row (<= S) — padding tokens and
    whole padding rows are routed to ``trash_page`` so one fused scatter
    covers the ragged batch;
    trash_page: page id reserved for discarded writes (never mapped);
    base: row offset added to every resolved page id — a
    ``PagedStackStore`` passes ``layer * pages_per_layer`` so per-layer
    tables index the flat pool (the per-layer trash lands at
    ``base + trash_page``).

    Returns (k_pages, v_pages) functionally updated.
    """
    B, S = k_new.shape[:2]
    page = k_pages.shape[1]
    max_tokens = block_table.shape[1] * page
    pos = start[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]   # (B,S)
    valid = jnp.arange(S, dtype=jnp.int32)[None, :] < new_lens[:, None]
    posc = jnp.minimum(pos, max_tokens - 1)  # clamp before table lookup
    pids = jnp.take_along_axis(block_table, posc // page, axis=1)
    pids = jnp.where(valid, pids, trash_page) + base
    offs = posc % page
    flat = lambda a: a.reshape(B * S, *a.shape[2:])  # noqa: E731
    k_pages = k_pages.at[flat(pids), flat(offs)].set(
        flat(k_new).astype(k_pages.dtype), mode="drop")
    v_pages = v_pages.at[flat(pids), flat(offs)].set(
        flat(v_new).astype(v_pages.dtype), mode="drop")
    return k_pages, v_pages


@dataclass
class PagedKVStore:
    """One layer's paged KV arrays; engine holds one per attention layer."""
    k_pages: jax.Array  # (P, page, KV, hd)
    v_pages: jax.Array

    @classmethod
    def create(cls, num_pages, page_size, kv_heads, head_dim,
               dtype=jnp.bfloat16):
        shape = (num_pages, page_size, kv_heads, head_dim)
        return cls(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))

    @property
    def page_size(self):
        return self.k_pages.shape[1]

    def write(self, k_new, v_new, page_ids, start: int):
        """Write S new tokens for ONE request.

        k_new/v_new: (S, KV, hd); page_ids: (n,) python/int32 array of the
        request's pages; start: the request's context length before this
        write. Returns updated store.
        """
        S = k_new.shape[0]
        page = self.page_size
        pos = start + jnp.arange(S)
        pids = jnp.asarray(page_ids)[pos // page]
        offs = pos % page
        k_pages = self.k_pages.at[pids, offs].set(
            k_new.astype(self.k_pages.dtype))
        v_pages = self.v_pages.at[pids, offs].set(
            v_new.astype(self.v_pages.dtype))
        return PagedKVStore(k_pages, v_pages)

    def write_batch(self, k_new, v_new, block_table, start, new_lens,
                    trash_page):
        """Batched multi-sequence scatter (see ``scatter_pages``)."""
        k_pages, v_pages = scatter_pages(
            self.k_pages, self.v_pages, k_new, v_new, block_table, start,
            new_lens, trash_page)
        return PagedKVStore(k_pages, v_pages)

    def gather(self, page_ids):
        """(n_pages,) -> contiguous (n_pages*page, KV, hd) k, v."""
        pids = jnp.asarray(page_ids)
        k = self.k_pages[pids].reshape(-1, *self.k_pages.shape[2:])
        v = self.v_pages[pids].reshape(-1, *self.v_pages.shape[2:])
        return k, v


jax.tree_util.register_pytree_node(
    PagedKVStore,
    lambda s: ((s.k_pages, s.v_pages), None),
    lambda _, c: PagedKVStore(*c),
)


@runtime_checkable
class PagedStore(Protocol):
    """The paged-store surface shared by the transformer's paged cache
    protocol and the serving executor (DESIGN.md §Ragged paged execution).

    A conforming store is a pytree whose array leaves ride the
    transformer ``lax.scan`` as **carry** — every method below must
    return leaves of unchanged shape/dtype (carry aliasing is what makes
    step time capacity-independent).  Per-layer addressing is explicit:
    ``write_batch``/``gather_batch``/``layer_table`` take the scan-step
    ``layer`` index and offset into the flat page pool; block tables
    stay in allocator page-id space (0..pages_per_layer-2, with
    ``pages_per_layer-1`` the per-layer trash page for ragged padding).

    Construction goes through ``build`` (the executor sizes
    ``pages_per_layer`` to allocator capacity + 1 trash page) and the
    prefix cache's copy-on-write boundary copy through ``copy_page``.
    """

    @property
    def pages_per_layer(self) -> int: ...

    @property
    def page_size(self) -> int: ...

    @property
    def trash_page(self) -> int: ...

    def write_batch(self, k_new, v_new, block_table, start, new_lens, *,
                    layer): ...

    def gather_batch(self, block_table, *, layer): ...

    def layer_table(self, block_table, layer): ...

    def copy_page(self, src, dst): ...


@dataclass
class PagedStackStore:
    """Paged KV for one stack of ``layers`` layers, flattened for scan
    carry: leaves are (layers * pages_per_layer, page, KV, hd) and layer
    ``l``'s page ``p`` is row ``l * pages_per_layer + p``.

    One per attention block position per scan stage.  The whole store
    rides the transformer's ``lax.scan`` as carry (the per-step layer
    index arrives as scan xs), so per-layer reads/writes are
    layer-offset gathers/scatters on resident pages only — no
    capacity-shaped restack per call.  The last page of every layer's
    range is that layer's trash page (ragged padding writes), which is
    why ``pages_per_layer`` is the allocator's ``num_pages + 1``.
    Donated under jit, XLA aliases the carry in place across iterations.

    The Pallas paged kernels need no layout awareness: ``layer_table``
    offsets a block table into the flat pool and the kernels just see a
    bigger page array.
    """
    k_pages: jax.Array
    v_pages: jax.Array
    layers: int          # static pytree aux (leading-row stride factor)

    @classmethod
    def build(cls, layers: int, pages_per_layer: int, page_size: int,
              kv_heads: int, head_dim: int, dtype=None):
        dtype = store_dtype() if dtype is None else dtype
        shape = (layers * pages_per_layer, page_size, kv_heads, head_dim)
        return cls(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype), layers)

    @property
    def pages_per_layer(self) -> int:
        return self.k_pages.shape[0] // self.layers

    @property
    def page_size(self) -> int:
        return self.k_pages.shape[1]

    @property
    def trash_page(self) -> int:
        return self.pages_per_layer - 1

    def layer_table(self, block_table, layer):
        """Per-layer block table -> absolute rows in the flat pool."""
        return block_table + layer * self.pages_per_layer

    def write_batch(self, k_new, v_new, block_table, start, new_lens, *,
                    layer):
        """Scatter one layer's new tokens (``layer`` may be traced — it
        is the scan's per-step index).  Values are rounded through bf16
        before landing so the container dtype never changes what a
        reader sees (see ``store_dtype``)."""
        k_pages, v_pages = scatter_pages(
            self.k_pages, self.v_pages,
            k_new.astype(jnp.bfloat16), v_new.astype(jnp.bfloat16),
            block_table, start, new_lens, self.trash_page,
            base=layer * self.pages_per_layer)
        return PagedStackStore(k_pages, v_pages, self.layers)

    def copy_page(self, src, dst) -> "PagedStackStore":
        """Copy one page's K/V across every layer of the stack — the
        prefix cache's copy-on-write boundary-page copy (src stays a
        valid cached page; dst becomes the claimer's private copy).
        ``src``/``dst`` may be traced scalars, so one jit signature
        serves every copy."""
        rows = jnp.arange(self.layers, dtype=jnp.int32) * \
            self.pages_per_layer

        def cp(a):
            return a.at[rows + dst].set(a[rows + src])
        return PagedStackStore(cp(self.k_pages), cp(self.v_pages),
                               self.layers)

    def gather_batch(self, block_table, *, layer):
        """One layer's view: (B, maxp) -> contiguous
        (B, maxp*page, KV, hd) k, v."""
        rows = self.layer_table(block_table, layer)
        B, maxp = block_table.shape
        k = self.k_pages[rows].reshape(B, -1, *self.k_pages.shape[-2:])
        v = self.v_pages[rows].reshape(B, -1, *self.v_pages.shape[-2:])
        return k, v

    # -- cross-replica page-chain migration (ISSUE 9) ----------------------
    def _page_rows(self, page: int):
        import numpy as np
        return np.arange(self.layers) * self.pages_per_layer + page

    def export_page(self, page: int):
        """One allocator page's K/V across every layer of the stack as a
        host array pair — the wire payload of the migration protocol
        (serving/migration.py checksums and chunks it). Shape
        (layers, page, KV, hd) each; dtype is the container dtype, whose
        values are bf16-rounded on every backend (see ``store_dtype``),
        so payload bytes round-trip bit-exactly between replicas."""
        import numpy as np
        rows = self._page_rows(page)
        return np.asarray(self.k_pages[rows]), np.asarray(self.v_pages[rows])

    def import_page(self, page: int, k, v) -> "PagedStackStore":
        """Write a transferred page payload (``export_page`` counterpart)
        into this store at ``page``. Off the hot path — migrations are
        rare operator events — so a plain functional update, no jit."""
        rows = self._page_rows(page)
        return PagedStackStore(
            self.k_pages.at[rows].set(jnp.asarray(k, self.k_pages.dtype)),
            self.v_pages.at[rows].set(jnp.asarray(v, self.v_pages.dtype)),
            self.layers)


jax.tree_util.register_pytree_node(
    PagedStackStore,
    lambda s: ((s.k_pages, s.v_pages), s.layers),
    lambda layers, c: PagedStackStore(c[0], c[1], layers),
)


@dataclass
class SlotStore:
    """Constant-size per-request state (SSM/xLSTM/conv): one slot per row."""
    data: dict  # name -> (slots, ...) arrays

    @classmethod
    def create(cls, num_slots: int, shapes: dict, dtypes: dict | None = None):
        dtypes = dtypes or {}
        return cls({name: jnp.zeros((num_slots,) + tuple(shape),
                                    dtypes.get(name, jnp.float32))
                    for name, shape in shapes.items()})

    def read(self, slot: int):
        return {k: v[slot] for k, v in self.data.items()}

    def write(self, slot: int, values: dict):
        return SlotStore({k: self.data[k].at[slot].set(values[k])
                          for k in self.data})


jax.tree_util.register_pytree_node(
    SlotStore,
    lambda s: (tuple(s.data.values()), tuple(s.data.keys())),
    lambda keys, vals: SlotStore(dict(zip(keys, vals))),
)
