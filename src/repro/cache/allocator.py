"""Page allocator with a ref-counted KV prefix cache.

This is the engine-side memory accounting for the KV cache (the substrate
the paper's memory-pressure experiments, §2.4/§4.3.2, exercise): capacity
is expressed in fixed-size pages; requests allocate pages as their context
grows and release them on completion/preemption. The scheduler consults
``can_allocate``/``utilization`` for admission and preemption decisions.

On top of the free-list substrate sits a **prefix cache** (ISSUE 4,
DESIGN.md §KV prefix cache): completed prefills publish their page chains
into a trie keyed by page-aligned *content runs*, so any later request
whose prompt shares a page-aligned prefix (same system prompt, same mm
payload — not just whole-prompt duplicates) re-uses the cached KV pages
instead of re-prefilling them:

  * every page carries a **reference count** = number of requests whose
    block tables include it; freeing a request only returns pages whose
    count drops to zero — shared pages survive any one owner's preemption
    or completion;
  * zero-ref pages that are still indexed stay **cached**: they hold
    reusable KV, count as free for admission (``available_pages``), and
    are evicted LRU, subtree-at-a-time, only when an allocation actually
    needs them;
  * the first *partially*-shared page is claimed **copy-on-write**: the
    donor's boundary page is copied into a fresh private page and the
    request resumes prefilling mid-page instead of at the page boundary.

All content identity is structural — chunks of ``(content_id, tokens)``
(see ``Request.content_chunks``) are re-cut into per-page run tuples, so
two prompts match exactly where their content matches. Private content ids
(containing ``"!"``) can never recur across requests, so chains never
extend past a private-led page and pure-text prompts without a shared
system prefix are skipped outright (no index growth, no match scans).
Content-addressed mm payloads *are* published even before any duplicate
exists — a later duplicate must find the chain — so mm-heavy workloads
grow an index bounded by KV capacity (zero-ref chains are the first thing
eviction reclaims under pressure); match lookups stay O(pages) via the
exact-key child dict plus first-run head buckets for the COW scan.

All operations are O(pages moved). ``check_invariants`` asserts refcount
conservation, free/owned/cached partitioning, and trie well-formedness.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field


class OutOfPages(Exception):
    pass


def iter_page_runs(chunks, page_size: int):
    """Re-cut content chunks ``[(content_id, tokens), ...]`` into pages.

    Yields ``(runs, tokens)`` per page in prompt order: ``runs`` is a
    tuple of ``(content_id, start_offset, length)`` segments covering the
    page and ``tokens`` its token count (== page_size except the final
    partial page). Two prompts produce equal run tuples for a page exactly
    when that page's token content is identical — the trie key.
    """
    runs: list = []
    filled = 0
    for cid, n in chunks:
        off = 0
        while off < n:
            take = min(n - off, page_size - filled)
            runs.append((cid, off, take))
            off += take
            filled += take
            if filled == page_size:
                yield tuple(runs), page_size
                runs, filled = [], 0
    if filled:
        yield tuple(runs), filled


def common_prefix_tokens(a, b) -> int:
    """Longest common leading token span of two page-run tuples."""
    common = 0
    for (c1, o1, l1), (c2, o2, l2) in zip(a, b):
        if c1 != c2 or o1 != o2:
            break
        common += min(l1, l2)
        if l1 != l2:
            break
    return common


def _shareable(cid: str) -> bool:
    """Private content ids (``"!"``) never recur across requests, so a
    page is only worth indexing while its *leading* run is shareable."""
    return "!" not in cid


@dataclass
class PrefixMatch:
    """Longest cached page-aligned prefix for one prompt (pure query)."""
    pages: list            # fully-shared pages, chain order
    tokens: int            # claimable tokens incl. the COW tail
    cow_src: int | None = None   # donor page for the partially-shared page
    cow_valid: int = 0           # leading tokens of cow_src valid here


class _Node:
    """One cached page in the prefix trie (the path is the chain hash)."""
    __slots__ = ("page", "runs", "parent", "children", "heads", "tick")

    def __init__(self, page, runs, parent):
        self.page = page
        self.runs = runs          # this node's key in parent.children
        self.parent = parent
        self.children: dict = {}  # runs tuple -> _Node
        # COW-candidate buckets: first-run (cid, offset) -> [children].
        # A partial match needs an identical first run, so the donor scan
        # only ever touches one bucket instead of every child (a busy
        # root can hold hundreds of unrelated chains).
        self.heads: dict = {}
        self.tick = 0             # LRU recency stamp

    def link(self, child: "_Node") -> None:
        self.children[child.runs] = child
        self.heads.setdefault(child.runs[0][:2], []).append(child)

    def unlink(self, child: "_Node") -> None:
        del self.children[child.runs]
        key = child.runs[0][:2]
        bucket = self.heads[key]
        bucket.remove(child)
        if not bucket:
            del self.heads[key]


@dataclass
class BlockAllocator:
    num_pages: int
    page_size: int
    _free: list[int] = field(default_factory=list)
    _owned: dict[str, list[int]] = field(default_factory=dict)

    def __post_init__(self):
        self._free = list(range(self.num_pages - 1, -1, -1))
        self._ref: dict[int, int] = {}        # page -> live owners
        self._root = _Node(None, (), None)
        self._node_of: dict[int, _Node] = {}  # cached page -> trie node
        self._cached_free: set[int] = set()   # cached AND zero-ref
        self._lru_heap: list[tuple[int, int]] = []  # (tick, page), lazy
        self._tick = 0
        # prefix-cache stats (surfaced via prefix_stats())
        self.prefix_hits = 0
        self.prefix_tokens_served = 0
        self.prefix_evictions = 0
        self.cow_copies = 0
        self.published_pages = 0
        self.imported_pages = 0   # pages installed via import_chain

    # -- queries ----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        """Pages on the raw free list (excludes evictable cached pages)."""
        return len(self._free)

    @property
    def evictable_pages(self) -> int:
        """Cached zero-ref pages: reusable KV, reclaimable on demand."""
        return len(self._cached_free)

    @property
    def available_pages(self) -> int:
        """What an allocation can actually draw on: free + evictable."""
        return len(self._free) + len(self._cached_free)

    @property
    def cached_pages(self) -> int:
        return len(self._node_of)

    @property
    def used_pages(self) -> int:
        return self.num_pages - self.available_pages

    def utilization(self) -> float:
        return self.used_pages / max(self.num_pages, 1)

    def pages_for_tokens(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def can_allocate(self, tokens: int, rid: str | None = None,
                     match: PrefixMatch | None = None) -> bool:
        """Would ``allocate`` (after an optional prefix claim) succeed?

        ``rid``: count the pages the request already owns, mirroring
        ``allocate``'s incremental ``need`` (a growth check for a request
        holding pages must not demand room for its whole context again).
        ``match``: shared pages come from the cache rather than the free
        list, but zero-ref matched pages (and the COW donor) stop being
        evictable the moment they are claimed, so they leave ``available``.
        """
        need = self.pages_for_tokens(tokens)
        if rid is not None:
            need -= len(self._owned.get(rid, ()))
        avail = len(self._free) + len(self._cached_free)
        if match is not None and match.tokens > 0:
            need -= len(match.pages)
            avail -= sum(1 for p in match.pages
                         if self._ref.get(p, 0) == 0)
            if match.cow_src is not None and \
                    self._ref.get(match.cow_src, 0) == 0:
                avail -= 1   # pinned while its copy is allocated
        return need <= avail

    def pages_of(self, rid: str) -> list[int]:
        return list(self._owned.get(rid, ()))

    def owned_pages(self, rid: str) -> int:
        return len(self._owned.get(rid, ()))

    def ref_count(self, page: int) -> int:
        return self._ref.get(page, 0)

    def owned_map(self) -> dict[str, tuple[int, ...]]:
        """Live ownership snapshot: rid -> page tuple in block-table
        order — what the lifecycle-journal replay oracle must reproduce
        bit-exactly (serving/journal.py)."""
        return {rid: tuple(ps) for rid, ps in self._owned.items() if ps}

    def export_hot_chains(self, max_pages: int) -> list[list]:
        """Hottest cached chains, for warming a restarted peer's trie:
        one greedy path per root chain — root children hottest-first,
        then the hottest child at every node — capped at ``max_pages``
        pages total. Entries are ``(runs, tokens, page)`` in chain
        order: the first two fields are the manifest shape
        ``import_chain`` consumes (every trie node is a full page), the
        third is where this allocator holds the payload."""
        out: list[list] = []
        budget = max_pages
        for root in sorted(self._root.children.values(),
                           key=lambda n: -n.tick):
            if budget <= 0:
                break
            chain, node = [], root
            while node is not None and budget > 0:
                chain.append((node.runs, self.page_size, node.page))
                budget -= 1
                node = max(node.children.values(),
                           key=lambda c: c.tick, default=None)
            if chain:
                out.append(chain)
        return out

    # -- prefix cache: match / claim / publish ----------------------------
    def match_prefix(self, chunks, limit_tokens: int) -> PrefixMatch:
        """Longest cached prefix of a prompt, capped at ``limit_tokens``
        (callers pass ``prompt_tokens - 1``: the last prompt token must
        always run through the model to produce the first output logits).

        Pure query — claims nothing; the result stays valid until the
        next ``allocate``/``claim_prefix`` (eviction only runs there).
        """
        pages: list[int] = []
        claimed = 0
        cow_src, cow_valid = None, 0
        if limit_tokens <= 0 or not chunks or not self._root.children \
                or not _shareable(chunks[0][0]):
            return PrefixMatch(pages, 0)   # empty index / private-led
        node = self._root
        for runs, ptoks in iter_page_runs(chunks, self.page_size):
            child = node.children.get(runs) if ptoks == self.page_size \
                else None
            if child is not None and claimed + self.page_size <= \
                    limit_tokens:
                node = child
                pages.append(child.page)
                claimed += self.page_size
                continue
            # first page that cannot be fully shared: the best partially-
            # matching cached sibling becomes the copy-on-write donor (a
            # partial match requires an identical first run, so only that
            # head bucket is scanned)
            best = 0
            if _shareable(runs[0][0]):
                for cand in node.heads.get(runs[0][:2], ()):
                    c = common_prefix_tokens(runs, cand.runs)
                    if c > best:
                        best, cow_src = c, cand.page
            cow_valid = min(best, limit_tokens - claimed, ptoks)
            if cow_valid <= 0:
                cow_src, cow_valid = None, 0
            break
        return PrefixMatch(pages, claimed + cow_valid, cow_src, cow_valid)

    def claim_prefix(self, rid: str,
                     match: PrefixMatch | None) -> tuple[int, int | None]:
        """Take ownership of a match for ``rid``: shared pages are
        ref-bumped in chain order (they become rows 0..k-1 of the
        request's block table); a COW donor gets a fresh private page
        allocated for its copy. Returns ``(claimed_tokens, cow_dst)``.

        Must run before any fresh allocation for ``rid`` (the page list
        is positional) and after a successful ``can_allocate(...,
        match=match)`` check.
        """
        if match is None or match.tokens <= 0:
            return 0, None
        owned = self._owned.setdefault(rid, [])
        assert not owned, f"{rid}: claim_prefix before fresh allocation"
        for p in match.pages:
            node = self._node_of[p]
            self._ref[p] = self._ref.get(p, 0) + 1
            if self._ref[p] == 1:
                self._cached_free.discard(p)
            self._touch(node)
            owned.append(p)
        cow_dst = None
        if match.cow_src is not None and match.cow_valid > 0:
            src = match.cow_src
            self._touch(self._node_of[src])
            # pin the donor while the copy's page is drawn (eviction for
            # that page must not reclaim — or hand back — the donor)
            pinned = self._ref.get(src, 0) == 0
            if pinned:
                self._cached_free.discard(src)
            cow_dst = self._pop_page()
            if pinned:
                self._cached_free.add(src)
            self._ref[cow_dst] = 1
            owned.append(cow_dst)
            self.cow_copies += 1
        self.prefix_hits += 1
        self.prefix_tokens_served += match.tokens
        return match.tokens, cow_dst

    def publish_prefix(self, rid: str, chunks,
                       max_tokens: int | None = None) -> int:
        """Index ``rid``'s prompt pages as a reusable chain (engine calls
        this when a prefill completes — the prompt KV is final and decode
        only ever writes *past* the prompt, so published pages are
        immutable). Chain pages must be fully shareable; the first
        full page mixing a shareable head with private tail content is
        published once as a COW donor, then the walk stops. An optional
        ``max_tokens`` truncates the chain the same way (the engine
        passes the popularity-gated prefix length, so content nobody
        else has asked for never bloats the index): the page containing
        token ``max_tokens`` is published once as a donor, then the walk
        stops. Re-publishing (same rid after preemption/re-admission) is
        a no-op; when another request published identical content first,
        the existing node wins and this rid's duplicate page stays
        private.
        """
        owned = self._owned.get(rid)
        if not owned or (max_tokens is not None and max_tokens <= 0):
            return 0
        node = self._root
        new = 0
        for i, (runs, ptoks) in enumerate(
                iter_page_runs(chunks, self.page_size)):
            if ptoks < self.page_size or i >= len(owned):
                break           # partial/unallocated tail: never indexed
            if not _shareable(runs[0][0]):
                break           # private-led page: unmatchable, stop
            if max_tokens is not None and i * self.page_size >= \
                    max_tokens:
                break           # wholly past the gated prefix
            child = node.children.get(runs)
            if child is not None:
                if child.page != owned[i]:
                    break       # same content cached first by another rid
            else:
                page = owned[i]
                if page in self._node_of:
                    break       # defensive: one chain per page
                child = _Node(page, runs, node)
                node.link(child)
                self._node_of[page] = child
                new += 1
            self._touch(child)
            node = child
            if any(not _shareable(cid) for cid, _o, _l in runs):
                break   # mixed boundary page: COW donor only, chain ends
            if max_tokens is not None and (i + 1) * self.page_size > \
                    max_tokens:
                break   # gated-prefix boundary page: donor, chain ends
        self.published_pages += new
        return new

    def import_chain(self, page_runs) -> list[tuple[int, int, bool]]:
        """Install a migrated page chain as *cached* trie content — the
        receive half of the ISSUE 9 page-chain transfer protocol. The
        prefix trie doubles as the transfer manifest: ``page_runs`` is a
        list of ``(runs, tokens)`` in chain order (the sender's trie
        path, already checksum-verified by the caller), and pages landing
        here are indexed zero-ref/evictable exactly as if a local prefill
        had published them — the migrated request then re-claims them
        through the ordinary ``match_prefix``/``claim_prefix`` admission
        flow, and dedup is free: positions whose content this allocator
        already caches are skipped, not re-allocated.

        Returns ``[(chain_index, page, fresh)]`` — ``fresh`` pages need
        their KV payload written (real executors scatter the transferred
        bytes in); pre-existing pages already hold identical-content KV.
        The walk stops early at a partial/private-led page (never
        shareable), when capacity is exhausted, or if eviction under
        pressure reclaimed the chain built so far — a shorter chain is
        still correct, the target just re-prefills a longer residual."""
        node = self._root
        out: list[tuple[int, int, bool]] = []
        for i, (runs, ptoks) in enumerate(page_runs):
            if ptoks < self.page_size or not _shareable(runs[0][0]):
                break
            child = node.children.get(runs)
            if child is None:
                if not self._free and not self._cached_free:
                    break   # no room for the rest of the chain
                page = self._pop_page()
                if node is not self._root and \
                        node.page not in self._node_of:
                    # the eviction inside _pop_page reclaimed our own
                    # freshly-imported chain (everything else was hotter):
                    # stop — linking to an unlinked node would corrupt
                    # the trie. Return the drawn page first.
                    self._free.append(page)
                    break
                child = _Node(page, runs, node)
                node.link(child)
                self._node_of[page] = child
                self._ref[page] = 0
                self._cached_free.add(page)
                self.imported_pages += 1
                out.append((i, page, True))
            else:
                out.append((i, child.page, False))
            self._touch(child)
            node = child
            if any(not _shareable(cid) for cid, _o, _l in runs):
                break   # mixed boundary page: COW donor only, chain ends
        return out

    def prefix_stats(self) -> dict:
        return {
            "hits": self.prefix_hits,
            "tokens_served": self.prefix_tokens_served,
            "published_pages": self.published_pages,
            "imported_pages": self.imported_pages,
            "evictions": self.prefix_evictions,
            "cow_copies": self.cow_copies,
            "cached_pages": len(self._node_of),
            "evictable_pages": len(self._cached_free),
        }

    # -- mutation ----------------------------------------------------------
    def allocate(self, rid: str, tokens: int) -> list[int]:
        """Ensure `rid` owns enough pages for `tokens` total tokens,
        evicting cold cached pages on demand."""
        have = len(self._owned.get(rid, ()))
        need = self.pages_for_tokens(tokens) - have
        if need <= 0:
            return []
        if need > len(self._free) + len(self._cached_free):
            raise OutOfPages(
                f"{rid}: need {need} pages, {len(self._free)} free + "
                f"{len(self._cached_free)} evictable")
        pages = [self._pop_page() for _ in range(need)]
        for p in pages:
            self._ref[p] = 1
        self._owned.setdefault(rid, []).extend(pages)
        return pages

    def free(self, rid: str) -> int:
        """Release ``rid``'s ownership. Shared pages survive while any
        other owner remains; zero-ref pages return to the free list —
        unless they are indexed, in which case they stay cached
        (evictable) so their KV remains reusable."""
        pages = self._owned.pop(rid, [])
        for p in pages:
            n = self._ref.get(p, 0) - 1
            if n > 0:
                self._ref[p] = n
                continue
            node = self._node_of.get(p)
            if node is not None:
                self._ref[p] = 0
                self._cached_free.add(p)
                self._touch(node)
            else:
                self._ref.pop(p, None)
                self._free.append(p)
        return len(pages)

    def _touch(self, node: _Node) -> None:
        self._tick += 1
        node.tick = self._tick
        if node.page in self._cached_free:
            heapq.heappush(self._lru_heap, (self._tick, node.page))

    def _pop_page(self) -> int:
        if not self._free:
            self._evict_lru()
        return self._free.pop()

    def _evict_lru(self) -> None:
        """Reclaim the least-recently-touched evictable chain. Evicting a
        node drops its whole subtree: descendants of a zero-ref node are
        zero-ref too (any owner of a page owns its entire prefix chain),
        so the cascade only ever frees cold pages."""
        while self._lru_heap:
            tick, page = heapq.heappop(self._lru_heap)
            node = self._node_of.get(page)
            if node is None or page not in self._cached_free or \
                    node.tick != tick:
                continue   # stale heap entry (re-touched or already gone)
            self._evict_subtree(node)
            return
        raise OutOfPages("eviction requested with no evictable pages")

    def _evict_subtree(self, node: _Node) -> None:
        # iterative post-order: a single video's chain can run thousands
        # of pages deep, far past Python's recursion limit
        stack, order = [node], []
        while stack:
            n = stack.pop()
            order.append(n)
            stack.extend(n.children.values())
        for n in reversed(order):        # children before parents
            assert self._ref.get(n.page, 0) == 0, \
                "evicting a referenced page"
            n.parent.unlink(n)
            del self._node_of[n.page]
            self._cached_free.discard(n.page)
            self._ref.pop(n.page, None)
            self._free.append(n.page)
            self.prefix_evictions += 1

    def check_invariants(self) -> None:
        owned_all: dict[int, int] = {}
        for rid, ps in self._owned.items():
            assert len(set(ps)) == len(ps), f"{rid}: duplicate page"
            for p in ps:
                owned_all[p] = owned_all.get(p, 0) + 1
        # refcount conservation: every page's count == number of owners
        for p, n in owned_all.items():
            assert self._ref.get(p) == n, \
                f"page {p}: ref {self._ref.get(p)} != owners {n}"
        for p, n in self._ref.items():
            assert n == owned_all.get(p, 0), \
                f"page {p}: ref {n} but {owned_all.get(p, 0)} owners"
        free = set(self._free)
        assert len(free) == len(self._free), "double-freed page"
        assert free.isdisjoint(owned_all), "page both owned and free"
        assert free.isdisjoint(self._node_of), "page both cached and free"
        # cached zero-ref pages are exactly the evictable set
        zero_cached = {p for p in self._node_of
                       if self._ref.get(p, 0) == 0}
        assert zero_cached == self._cached_free, \
            "evictable set out of sync with zero-ref cached pages"
        assert len(free) + len(owned_all) + len(self._cached_free) == \
            self.num_pages, "page leak"
        # trie well-formedness + sharing monotonicity: every owner of a
        # page owns its whole prefix, so parent refs dominate child refs
        stack = [self._root]
        seen_pages = set()
        while stack:
            node = stack.pop()
            in_buckets = [c for b in node.heads.values() for c in b]
            assert sorted(id(c) for c in in_buckets) == \
                sorted(id(c) for c in node.children.values()), \
                "head buckets out of sync with children"
            for key, child in node.children.items():
                assert child.parent is node and child.runs == key
                assert child in node.heads.get(key[0][:2], ()), \
                    "child missing from its head bucket"
                assert child.page not in seen_pages, "page in two chains"
                seen_pages.add(child.page)
                if node is not self._root:
                    assert self._ref.get(node.page, 0) >= \
                        self._ref.get(child.page, 0), \
                        "child page more referenced than its prefix"
                stack.append(child)
        assert seen_pages == set(self._node_of), \
            "trie nodes out of sync with the page index"
