"""Page allocator: the engine-side memory accounting for the KV cache.

This is the substrate the paper's memory-pressure experiments (§2.4, §4.3.2)
exercise: KV capacity is expressed in fixed-size pages; requests allocate
pages as their context grows and free them on completion/preemption. The
scheduler consults ``can_allocate``/``utilization`` for admission and
preemption decisions.

All operations are O(pages moved): the free list is a stack and ownership
is a dict of page lists. The engine only calls ``allocate`` for a decoding
request when its context crosses a page boundary (DESIGN.md §Incremental
scheduling core), so steady-state decode does zero allocator work.
"""
from __future__ import annotations

from dataclasses import dataclass, field


class OutOfPages(Exception):
    pass


@dataclass
class BlockAllocator:
    num_pages: int
    page_size: int
    _free: list[int] = field(default_factory=list)
    _owned: dict[str, list[int]] = field(default_factory=dict)

    def __post_init__(self):
        self._free = list(range(self.num_pages - 1, -1, -1))

    # -- queries ----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    def utilization(self) -> float:
        return self.used_pages / max(self.num_pages, 1)

    def pages_for_tokens(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def can_allocate(self, tokens: int) -> bool:
        return self.pages_for_tokens(tokens) <= self.free_pages

    def pages_of(self, rid: str) -> list[int]:
        return list(self._owned.get(rid, ()))

    def owned_pages(self, rid: str) -> int:
        return len(self._owned.get(rid, ()))

    # -- mutation ----------------------------------------------------------
    def allocate(self, rid: str, tokens: int) -> list[int]:
        """Ensure `rid` owns enough pages for `tokens` total tokens."""
        have = len(self._owned.get(rid, ()))
        need = self.pages_for_tokens(tokens) - have
        if need <= 0:
            return []
        if need > len(self._free):
            raise OutOfPages(
                f"{rid}: need {need} pages, {len(self._free)} free")
        pages = [self._free.pop() for _ in range(need)]
        self._owned.setdefault(rid, []).extend(pages)
        return pages

    def free(self, rid: str) -> int:
        pages = self._owned.pop(rid, [])
        self._free.extend(pages)
        return len(pages)

    def check_invariants(self) -> None:
        owned = [p for ps in self._owned.values() for p in ps]
        assert len(set(owned)) == len(owned), "double-allocated page"
        assert set(owned).isdisjoint(self._free), "page both owned and free"
        assert len(owned) + len(self._free) == self.num_pages, "page leak"
