"""Correctness tests for the beyond-paper optimizations (EXPERIMENTS §Perf):
int8 KV cache numerics, grouped-GQA equivalence, MoE bf16 combine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.models.params import init_params

KEY = jax.random.PRNGKey(0)


def test_int8_kv_cache_close_to_f32():
    """Decode logits with int8 KV stay close to the f32-cache reference."""
    cfg = dataclasses.replace(get_reduced("chatglm3_6b"), dtype=jnp.float32)
    params = init_params(T.model_decls(cfg), KEY)
    B, P = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P + 1), 0,
                              cfg.vocab_size)

    def run(kv_dtype):
        cache = init_params(T.cache_decls(cfg, B, 64, dtype=kv_dtype), KEY)
        _, cache, _ = T.forward(params, cfg, toks[:, :P], cache=cache)
        lg, _, _ = T.forward(params, cfg, toks[:, P:],
                             positions=jnp.full((B, 1), P), cache=cache,
                             q_start=P)
        return jax.nn.softmax(lg[:, 0].astype(jnp.float32))

    ref = run(jnp.float32)
    q8 = run(jnp.int8)
    # probability distributions should be close despite 8-bit KV
    tv = 0.5 * float(jnp.abs(ref - q8).sum(-1).max())
    assert tv < 0.05, f"int8 KV total-variation too high: {tv}"


def test_grouped_gqa_equals_repeat_reference():
    """mha's grouped GQA path == explicit kv-head repetition."""
    from repro.models.layers import mha
    B, Tq, Tk, H, KV, hd = 2, 8, 12, 8, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Tq, H, hd))
    k = jax.random.normal(ks[1], (B, Tk, KV, hd))
    v = jax.random.normal(ks[2], (B, Tk, KV, hd))
    mask = jnp.tril(jnp.ones((Tq, Tk), bool), k=Tk - Tq)[None, None]
    out = mha(q, k, v, mask)
    kr = jnp.repeat(k, H // KV, axis=2)
    vr = jnp.repeat(v, H // KV, axis=2)
    ref = mha(q, kr, vr, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_sliding_window_ignores_stale_context():
    """Decode with window W must be unaffected by K/V entries older than W
    — the invariant that makes window-sized caches valid (§Perf, gemma)."""
    cfg = dataclasses.replace(get_reduced("gemma3_27b"), dtype=jnp.float32,
                              sliding_window=16, local_global_period=0)
    params = init_params(T.model_decls(cfg), KEY)
    B, P = 1, 40
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, P + 1), 0,
                              cfg.vocab_size)

    def run(corrupt_old):
        cache = init_params(T.cache_decls(cfg, B, 64, dtype=jnp.float32), KEY)
        _, cache, _ = T.forward(params, cfg, toks[:, :P], cache=cache)
        if corrupt_old:
            # trash all K/V entries strictly older than the window
            def trash(tree):
                out = dict(tree)
                for key in ("k", "v"):
                    if key in out:
                        arr = out[key]
                        out[key] = arr.at[:, :, :P - 16].set(99.0)
                return out
            cache = {
                "stages": [{b: trash(blk) for b, blk in st.items()}
                           for st in cache["stages"]],
                "idx": cache["idx"],
            }
        lg, _, _ = T.forward(params, cfg, toks[:, P:],
                             positions=jnp.full((B, 1), P), cache=cache,
                             q_start=P)
        return lg[:, 0]

    np.testing.assert_allclose(np.asarray(run(False)),
                               np.asarray(run(True)), atol=1e-6)


def test_flash_kernel_model_path_equivalence():
    """Full model forward with the Pallas flash kernel == jnp attention."""
    from repro.models import layers as L
    cfg = dataclasses.replace(get_reduced("chatglm3_6b"), dtype=jnp.float32,
                              num_layers=2)
    params = init_params(T.model_decls(cfg), KEY)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 24), 0,
                              cfg.vocab_size)
    ref, _, _ = T.forward(params, cfg, toks)
    L.set_flash_kernel(True)
    try:
        out, _, _ = T.forward(params, cfg, toks)
    finally:
        L.set_flash_kernel(False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-4)
