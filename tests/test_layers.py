"""Layer-level unit tests: RoPE variants, MoE routing, norms."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.rope import apply_rope

KEY = jax.random.PRNGKey(0)


def _x(B=2, S=8, H=4, D=32):
    return jax.random.normal(KEY, (B, S, H, D))


def _pos(B=2, S=8):
    return jnp.broadcast_to(jnp.arange(S)[None], (B, S))


def test_rope_preserves_norm():
    x = _x()
    for style in ["llama", "half", "mrope"]:
        pos = _pos() if style != "mrope" else jnp.broadcast_to(
            _pos()[..., None], (2, 8, 3))
        y = apply_rope(x, pos, style=style)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)


def test_rope_position_zero_is_identity():
    x = _x()
    y = apply_rope(x, jnp.zeros((2, 8), jnp.int32), style="llama")
    np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)


def test_rope_relative_property():
    """q.k dot products depend only on relative positions (llama rope)."""
    D = 32
    q = jax.random.normal(KEY, (1, 1, 1, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, D))

    def dot_at(pq, pk):
        qr = apply_rope(q, jnp.array([[pq]]), style="llama")
        kr = apply_rope(k, jnp.array([[pk]]), style="llama")
        return float(jnp.sum(qr * kr))

    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3


def test_rope_half_leaves_second_half_untouched():
    x = _x()
    y = apply_rope(x, _pos(), style="half")
    D = x.shape[-1]
    np.testing.assert_allclose(np.asarray(x[..., D // 2:]),
                               np.asarray(y[..., D // 2:]), atol=1e-6)


def test_mrope_equal_streams_matches_llama():
    """With identical t/h/w position streams, M-RoPE == standard RoPE."""
    x = _x()
    pos3 = jnp.broadcast_to(_pos()[..., None], (2, 8, 3))
    y_m = apply_rope(x, pos3, style="mrope")
    y_l = apply_rope(x, _pos(), style="llama")
    np.testing.assert_allclose(np.asarray(y_m), np.asarray(y_l), atol=1e-5)


def _moe_cfg(E=4, K=2, cf=2.0):
    return ModelConfig(name="t", arch_type="moe", num_layers=1, d_model=32,
                       num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=64,
                       num_experts=E, experts_per_token=K, moe_every=1,
                       capacity_factor=cf, dtype=jnp.float32)


def _moe_params(cfg, key):
    from repro.models.params import init_params
    from repro.models.transformer import _moe_decls
    return init_params(_moe_decls(cfg), key)


def test_moe_output_shape_and_finite():
    cfg = _moe_cfg()
    p = _moe_params(cfg, KEY)
    x = jax.random.normal(KEY, (2, 16, 32))
    y, aux = L.moe_block(x, p, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux) > 0


def test_moe_dropless_capacity_is_permutation_invariant():
    """With cf = E/K (dropless), permuting tokens permutes outputs."""
    cfg = _moe_cfg(cf=2.0)  # E/K = 4/2 = 2 -> dropless
    p = _moe_params(cfg, KEY)
    x = jax.random.normal(KEY, (1, 16, 32))
    perm = jax.random.permutation(jax.random.PRNGKey(2), 16)
    y1, _ = L.moe_block(x, p, cfg)
    y2, _ = L.moe_block(x[:, perm], p, cfg)
    np.testing.assert_allclose(np.asarray(y1[:, perm]), np.asarray(y2),
                               atol=1e-4)


def test_moe_aux_loss_balanced_is_lower():
    """A uniform router yields lower aux loss than a collapsed one."""
    cfg = _moe_cfg()
    p = _moe_params(cfg, KEY)
    x = jax.random.normal(KEY, (2, 64, 32))
    p_collapsed = dict(p)
    p_collapsed["router"] = p["router"] * 0 + jnp.array(
        [10.0, -10, -10, -10])  # all tokens -> expert 0
    _, aux_norm = L.moe_block(x, p, cfg)
    _, aux_coll = L.moe_block(x, p_collapsed, cfg)
    assert float(aux_coll) > float(aux_norm)


def test_rms_norm_scale_invariance_direction():
    x = jax.random.normal(KEY, (2, 4, 32))
    w = jnp.zeros(32)
    y1 = L.rms_norm(x, w)
    y2 = L.rms_norm(3.7 * x, w)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_causal_mask_window():
    m = L.causal_mask(q_start=4, q_len=2, kv_len=8, window=3)
    # query global pos 4 sees kv {2,3,4}; pos 5 sees {3,4,5}
    np.testing.assert_array_equal(
        np.asarray(m),
        np.array([[0, 0, 1, 1, 1, 0, 0, 0],
                  [0, 0, 0, 1, 1, 1, 0, 0]], bool))
