"""Pallas kernel validation: shape/dtype sweeps against the ref.py oracles
(interpret mode on CPU), plus hypothesis property tests on invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops
from repro.kernels.ref import ref_paged_attention, ref_prefill_attention

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Skv,H,KV,hd", [
    (1, 8, 8, 2, 2, 32),      # MHA, no history
    (2, 16, 48, 4, 2, 64),    # GQA, chunked (history = 32)
    (1, 24, 40, 8, 1, 128),   # MQA, odd chunk size
    (2, 5, 21, 4, 4, 64),     # non-divisible by block sizes -> padding
])
def test_prefill_kernel_sweep(B, Sq, Skv, H, KV, hd, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Skv, KV, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Skv, KV, hd)).astype(dtype)
    q_start = Skv - Sq
    out = ops.prefill_attention(q, k, v, q_start=q_start)
    ref = ref_prefill_attention(q, k, v, q_start=q_start)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=_tol(dtype))


@pytest.mark.parametrize("window,softcap", [(0, 0.0), (8, 0.0), (0, 30.0),
                                            (16, 50.0)])
def test_prefill_kernel_window_softcap(window, softcap):
    B, Sq, Skv, H, KV, hd = 2, 16, 48, 4, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd))
    k = jax.random.normal(ks[1], (B, Skv, KV, hd))
    v = jax.random.normal(ks[2], (B, Skv, KV, hd))
    out = ops.prefill_attention(q, k, v, q_start=32, window=window,
                                softcap=softcap)
    ref = ref_prefill_attention(q, k, v, q_start=32, window=window,
                                softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,hd,P,page,mp", [
    (1, 2, 2, 32, 8, 8, 2),
    (2, 4, 2, 64, 16, 8, 4),
    (3, 8, 1, 128, 32, 16, 3),
    (2, 8, 8, 64, 16, 4, 5),
])
def test_paged_kernel_sweep(B, H, KV, hd, P, page, mp, dtype):
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, H, hd)).astype(dtype)
    kp = jax.random.normal(ks[1], (P, page, KV, hd)).astype(dtype)
    vp = jax.random.normal(ks[2], (P, page, KV, hd)).astype(dtype)
    bt = jax.random.randint(ks[3], (B, mp), 0, P)
    lengths = jax.random.randint(ks[4], (B,), 1, mp * page + 1)
    out = ops.paged_attention(q, kp, vp, bt, lengths)
    ref = ref_paged_attention(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=_tol(dtype))


def test_paged_kernel_ignores_unmapped_pages():
    """Entries of the page table beyond `length` must not affect output."""
    B, H, KV, hd, P, page, mp = 1, 4, 2, 32, 8, 4, 4
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (P, page, KV, hd))
    vp = jax.random.normal(ks[2], (P, page, KV, hd))
    lengths = jnp.array([6], jnp.int32)  # only pages 0-1 used
    bt1 = jnp.array([[0, 1, 2, 3]], jnp.int32)
    bt2 = jnp.array([[0, 1, 7, 5]], jnp.int32)  # junk tail
    o1 = ops.paged_attention(q, kp, vp, bt1, lengths)
    o2 = ops.paged_attention(q, kp, vp, bt2, lengths)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(sq=st.integers(1, 12), hist=st.integers(0, 12),
       h=st.sampled_from([2, 4]), kv=st.sampled_from([1, 2]))
def test_prefill_kernel_property(sq, hist, h, kv):
    """Property: kernel == oracle for arbitrary chunk/history splits."""
    hd = 32
    skv = hist + sq
    ks = jax.random.split(jax.random.PRNGKey(sq * 100 + hist), 3)
    q = jax.random.normal(ks[0], (1, sq, h, hd))
    k = jax.random.normal(ks[1], (1, skv, kv, hd))
    v = jax.random.normal(ks[2], (1, skv, kv, hd))
    out = ops.prefill_attention(q, k, v, q_start=hist)
    ref = ref_prefill_attention(q, k, v, q_start=hist)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


# ---------------- paged chunked-prefill kernel ------------------------------

def _paged_prefill_case(hists, chunks, *, page=4, KV=2, H=4, hd=32, seed=0,
                        junk_tail=15, maxp=None):
    """Write per-row history+chunk into disjoint pages; return kernel args.
    Block-table tails beyond each row's live pages hold ``junk_tail``."""
    from repro.cache.paged import PagedKVStore
    B = len(hists)
    totals = [h + c for h, c in zip(hists, chunks)]
    n_pages = [max(1, -(-t // page)) for t in totals]
    if maxp is None:
        maxp = max(n_pages)
    P = sum(n_pages) + 1
    store = PagedKVStore.create(P, page, KV, hd, dtype=jnp.float32)
    ks = jax.random.split(jax.random.PRNGKey(seed), 2 * B + 1)
    S = max(max(chunks), 1)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    bt_rows, nxt = [], 0
    for b in range(B):
        pages = list(range(nxt, nxt + n_pages[b]))
        nxt += n_pages[b]
        if totals[b]:
            k = jax.random.normal(ks[1 + 2 * b], (totals[b], KV, hd))
            v = jax.random.normal(ks[2 + 2 * b], (totals[b], KV, hd))
            store = store.write(k, v, pages, start=0)
        bt_rows.append((pages + [junk_tail] * maxp)[:maxp])
    bt = jnp.asarray(bt_rows, jnp.int32)
    return (q, store.k_pages, store.v_pages, bt,
            jnp.asarray(hists, jnp.int32), jnp.asarray(chunks, jnp.int32))


@pytest.mark.parametrize("hists,chunks", [
    ([0], [1]),               # no history, single token
    ([0, 5, 9], [6, 4, 0]),   # ragged incl. a length-0 row
    ([3], [6]),               # chunk crosses a page boundary mid-write
    ([4, 8], [4, 8]),         # history and chunk both page-aligned
    ([2, 2, 2, 2, 2], [3, 3, 3, 3, 3]),  # batch crossing a pow2 boundary
])
def test_paged_prefill_kernel_matches_ref(hists, chunks):
    from repro.kernels.ref import ref_paged_prefill_attention
    args = _paged_prefill_case(hists, chunks)
    out = ops.paged_prefill_attention(*args)
    ref = ref_paged_prefill_attention(*args)
    assert not np.isnan(np.asarray(out)).any()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@pytest.mark.parametrize("H,KV", [(4, 4), (4, 2), (8, 1)])  # MHA/GQA/MQA
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_paged_prefill_kernel_gqa_softcap(H, KV, softcap):
    from repro.kernels.ref import ref_paged_prefill_attention
    args = _paged_prefill_case([5, 0, 9], [6, 4, 2], H=H, KV=KV, seed=2)
    out = ops.paged_prefill_attention(*args, softcap=softcap)
    ref = ref_paged_prefill_attention(*args, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_paged_prefill_length_zero_rows_are_exact_zero():
    args = _paged_prefill_case([0, 7], [0, 3])
    out = np.asarray(ops.paged_prefill_attention(*args))
    assert (out[0] == 0).all()
    # padding query positions of the live row are zeroed too
    assert (out[1, 3:] == 0).all() and np.abs(out[1, :3]).sum() > 0


@settings(max_examples=12, deadline=None)
@given(hist=st.integers(0, 13), chunk=st.integers(1, 9),
       h=st.sampled_from([2, 4]), kv=st.sampled_from([1, 2]),
       pad_pages=st.integers(0, 3))
def test_paged_prefill_kernel_property(hist, chunk, h, kv, pad_pages):
    """Property: kernel == oracle for arbitrary history/chunk splits and
    padded (bucketed) table widths; the chunk attends over pages only."""
    from repro.kernels.ref import ref_paged_prefill_attention
    live = max(1, -(-(hist + chunk) // 4))
    args = _paged_prefill_case([hist], [chunk], H=h, KV=kv,
                               seed=hist * 100 + chunk,
                               maxp=live + pad_pages)
    out = ops.paged_prefill_attention(*args)
    ref = ref_paged_prefill_attention(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_paged_prefill_kernel_matches_contiguous_oracle():
    """Paged kernel == the dense chunked-prefill oracle on the same
    history/chunk (ties the paged path to the non-paged ground truth)."""
    from repro.cache.paged import PagedKVStore
    from repro.kernels.ref import ref_prefill_attention
    page, KV, H, hd = 4, 2, 4, 32
    hist, chunk = 9, 6
    ks = jax.random.split(KEY, 3)
    k = jax.random.normal(ks[0], (hist + chunk, KV, hd))
    v = jax.random.normal(ks[1], (hist + chunk, KV, hd))
    q = jax.random.normal(ks[2], (1, chunk, H, hd))
    pages = [7, 2, 9, 4]
    store = PagedKVStore.create(12, page, KV, hd, dtype=jnp.float32)
    store = store.write(k, v, pages, start=0)
    out = ops.paged_prefill_attention(
        q, store.k_pages, store.v_pages, jnp.asarray([pages], jnp.int32),
        jnp.asarray([hist], jnp.int32), jnp.asarray([chunk], jnp.int32))
    ref = ref_prefill_attention(q, k[None], v[None], q_start=hist)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_paged_kernel_clamped_padding_dma_matches_ref():
    """The decode kernel's index_map clamps padded grid steps to the
    row's last live page (no trash-page DMA per masked step); outputs
    must be unchanged — including rows whose table is almost all padding
    and a length-0 row whose clamp floor is page 0."""
    from repro.kernels.ref import ref_paged_attention
    B, H, KV, hd, P, page, mp = 3, 4, 2, 32, 16, 4, 8
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (P, page, KV, hd))
    vp = jax.random.normal(ks[2], (P, page, KV, hd))
    lengths = jnp.asarray([5, 0, 32], jnp.int32)   # 2 live pages / 0 / all
    bt = jax.random.randint(ks[3], (B, mp), 0, P)
    out = ops.paged_attention(q, kp, vp, bt, lengths)
    ref = ref_paged_attention(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
    # junk in the padded tail (incl. out-of-range-looking last page id)
    # cannot leak into the output through the clamped restaging
    bt2 = bt.at[:, 2:].set(P - 1)
    out2 = ops.paged_attention(q, kp, vp, bt2.at[0, 2:].set(11), lengths)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(out2[0]),
                               atol=1e-6)


def test_prefill_chunks_equal_full():
    """Running prefill in two chunks == one full pass (engine invariant)."""
    B, S, H, KV, hd = 1, 32, 4, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    full = ops.prefill_attention(q, k, v, q_start=0)
    c1 = ops.prefill_attention(q[:, :16], k[:, :16], v[:, :16], q_start=0)
    c2 = ops.prefill_attention(q[:, 16:], k, v, q_start=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([c1, c2], 1)),
                               np.asarray(full), atol=2e-5)
