"""Pallas kernel validation: shape/dtype sweeps against the ref.py oracles
(interpret mode on CPU), plus hypothesis property tests on invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops
from repro.kernels.ref import ref_paged_attention, ref_prefill_attention

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Skv,H,KV,hd", [
    (1, 8, 8, 2, 2, 32),      # MHA, no history
    (2, 16, 48, 4, 2, 64),    # GQA, chunked (history = 32)
    (1, 24, 40, 8, 1, 128),   # MQA, odd chunk size
    (2, 5, 21, 4, 4, 64),     # non-divisible by block sizes -> padding
])
def test_prefill_kernel_sweep(B, Sq, Skv, H, KV, hd, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Skv, KV, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Skv, KV, hd)).astype(dtype)
    q_start = Skv - Sq
    out = ops.prefill_attention(q, k, v, q_start=q_start)
    ref = ref_prefill_attention(q, k, v, q_start=q_start)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=_tol(dtype))


@pytest.mark.parametrize("window,softcap", [(0, 0.0), (8, 0.0), (0, 30.0),
                                            (16, 50.0)])
def test_prefill_kernel_window_softcap(window, softcap):
    B, Sq, Skv, H, KV, hd = 2, 16, 48, 4, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd))
    k = jax.random.normal(ks[1], (B, Skv, KV, hd))
    v = jax.random.normal(ks[2], (B, Skv, KV, hd))
    out = ops.prefill_attention(q, k, v, q_start=32, window=window,
                                softcap=softcap)
    ref = ref_prefill_attention(q, k, v, q_start=32, window=window,
                                softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,hd,P,page,mp", [
    (1, 2, 2, 32, 8, 8, 2),
    (2, 4, 2, 64, 16, 8, 4),
    (3, 8, 1, 128, 32, 16, 3),
    (2, 8, 8, 64, 16, 4, 5),
])
def test_paged_kernel_sweep(B, H, KV, hd, P, page, mp, dtype):
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, H, hd)).astype(dtype)
    kp = jax.random.normal(ks[1], (P, page, KV, hd)).astype(dtype)
    vp = jax.random.normal(ks[2], (P, page, KV, hd)).astype(dtype)
    bt = jax.random.randint(ks[3], (B, mp), 0, P)
    lengths = jax.random.randint(ks[4], (B,), 1, mp * page + 1)
    out = ops.paged_attention(q, kp, vp, bt, lengths)
    ref = ref_paged_attention(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=_tol(dtype))


def test_paged_kernel_ignores_unmapped_pages():
    """Entries of the page table beyond `length` must not affect output."""
    B, H, KV, hd, P, page, mp = 1, 4, 2, 32, 8, 4, 4
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (P, page, KV, hd))
    vp = jax.random.normal(ks[2], (P, page, KV, hd))
    lengths = jnp.array([6], jnp.int32)  # only pages 0-1 used
    bt1 = jnp.array([[0, 1, 2, 3]], jnp.int32)
    bt2 = jnp.array([[0, 1, 7, 5]], jnp.int32)  # junk tail
    o1 = ops.paged_attention(q, kp, vp, bt1, lengths)
    o2 = ops.paged_attention(q, kp, vp, bt2, lengths)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(sq=st.integers(1, 12), hist=st.integers(0, 12),
       h=st.sampled_from([2, 4]), kv=st.sampled_from([1, 2]))
def test_prefill_kernel_property(sq, hist, h, kv):
    """Property: kernel == oracle for arbitrary chunk/history splits."""
    hd = 32
    skv = hist + sq
    ks = jax.random.split(jax.random.PRNGKey(sq * 100 + hist), 3)
    q = jax.random.normal(ks[0], (1, sq, h, hd))
    k = jax.random.normal(ks[1], (1, skv, kv, hd))
    v = jax.random.normal(ks[2], (1, skv, kv, hd))
    out = ops.prefill_attention(q, k, v, q_start=hist)
    ref = ref_prefill_attention(q, k, v, q_start=hist)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_prefill_chunks_equal_full():
    """Running prefill in two chunks == one full pass (engine invariant)."""
    B, S, H, KV, hd = 1, 32, 4, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    full = ops.prefill_attention(q, k, v, q_start=0)
    c1 = ops.prefill_attention(q[:, :16], k[:, :16], v[:, :16], q_start=0)
    c2 = ops.prefill_attention(q[:, 16:], k, v, q_start=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([c1, c2], 1)),
                               np.asarray(full), atol=2e-5)
