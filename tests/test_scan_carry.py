"""Scan-carry paged stores: capacity-independent batched steps.

The transformer's layer scan must consume the ``PagedStackStore`` page
arrays as scan *carry* (donated, aliased in place), never as xs/ys — the
old layout restacked the whole store every call, so step time scaled
with KV *capacity* instead of live tokens (DESIGN.md §Ragged paged
execution). Three layers of assertion:

* jaxpr-level: the jitted step's scans emit **no capacity-shaped ys**,
  and the store-shaped arrays ride in the carry;
* compiled-level: donation holds (input buffers consumed) and the
  executable's temp allocation is a small fraction of store bytes;
* wall-clock: decode step time at fixed live tokens stays flat across a
  1x/4x/8x ``num_pages`` sweep, with bit-exact emitted-token parity and
  identical jit keys across capacities.
"""
import statistics
import time

import numpy as np
import pytest

from repro.cache import BlockAllocator
from repro.serving.executors import ExecutorConfig, ModelExecutor
from repro.serving.request import Modality, Request, State


def _cfg():
    from repro.configs import get_reduced
    return get_reduced("chatglm3-6b")


def _mk(rid: str, prompt: int, out: int = 64) -> Request:
    return Request(rid=rid, modality=Modality.TEXT, arrival=0.0,
                   text_tokens=prompt, prompt_tokens=prompt,
                   output_tokens=out)


def _setup(num_pages: int, batch: int = 4, prompt: int = 40):
    """Executor + requests prefilled and warmed into steady-state decode."""
    ex = ModelExecutor(_cfg(), ExecutorConfig(max_slots=8, max_len=256,
                                              num_pages=num_pages))
    alloc = BlockAllocator(num_pages=num_pages, page_size=16)
    ex.bind_allocator(alloc)
    reqs = [_mk(f"cap{i}", prompt, out=500) for i in range(batch)]
    for r in reqs:
        alloc.allocate(r.rid, prompt + 40)
        r.state = State.PREFILLING
    ex.run_iteration([(r, prompt) for r in reqs], [], [])
    for r in reqs:
        r.prefilled, r.state, r.decoded = prompt, State.RUNNING, 1
    for _ in range(2):          # compile + warm the decode signature
        ex.run_iteration([], reqs, [])
        for r in reqs:
            r.decoded += 1
    return ex, reqs


def _store_leaf_shapes(ex):
    import jax
    return {leaf.shape for leaf in jax.tree.leaves(ex._stores)}


def _scan_eqns(jaxpr):
    """All scan equations, recursing into sub-jaxprs."""
    found = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "scan":
            found.append(eqn)
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", None)
            if sub is not None:
                found.extend(_scan_eqns(sub))
    return found


def test_decode_step_scans_carry_stores_and_emit_no_capacity_ys():
    """Jaxpr of the jitted step: every store-shaped array is scan
    *carry*; no scan ys (the per-step stacked outputs) has a
    capacity-shaped aval — the structural guarantee that no call
    restacks the page arrays."""
    import jax
    import jax.numpy as jnp
    ex, reqs = _setup(num_pages=64)
    store_shapes = _store_leaf_shapes(ex)
    B, maxp = 4, 4
    toks = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.zeros((B, 1), jnp.int32)
    bt = jnp.zeros((B, maxp), jnp.int32)
    lengths = jnp.full((B,), 40, jnp.int32)
    new_lens = jnp.ones((B,), jnp.int32)
    jaxpr = jax.make_jaxpr(ex._prefill_step)(
        ex.params, ex._stores, toks, pos, bt, lengths, new_lens).jaxpr
    scans = _scan_eqns(jaxpr)
    assert scans, "batched step no longer lowers through lax.scan"
    carry_shapes = set()
    for eqn in scans:
        n_carry = eqn.params["num_carry"]
        for v in eqn.outvars[:n_carry]:
            carry_shapes.add(v.aval.shape)
        ys_avals = [v.aval for v in eqn.outvars[n_carry:]]
        bad = [a for a in ys_avals if a.shape in store_shapes]
        assert not bad, f"scan emits capacity-shaped ys: {bad}"
    assert store_shapes <= carry_shapes, \
        "paged stores are no longer scan carry"


def _decode_temp_bytes(ex):
    jnp = ex.jnp
    B, maxp = 4, 4
    args = (ex.params, ex._stores, jnp.zeros((B, 1), jnp.int32),
            jnp.zeros((B, 1), jnp.int32), jnp.zeros((B, maxp), jnp.int32),
            jnp.full((B,), 40, jnp.int32), jnp.ones((B,), jnp.int32))
    ma = ex._prefill_jit.lower(*args).compile().memory_analysis()
    return None if ma is None else ma.temp_size_in_bytes


def test_decode_step_donates_stores_and_temp_memory_is_capacity_free():
    """Compiled-level: the store buffers are donated (inputs consumed in
    place) and the executable's temp allocation does not grow with KV
    capacity — the model has a fixed temp footprint (activations,
    logits), but a capacity-sized copy anywhere would add temps on the
    order of the store-size delta between the two capacities."""
    import jax
    ex_small, _ = _setup(num_pages=64)
    ex_big, reqs = _setup(num_pages=512)
    store_bytes = {
        name: sum(leaf.size * leaf.dtype.itemsize
                  for leaf in jax.tree.leaves(e._stores))
        for name, e in (("small", ex_small), ("big", ex_big))}
    old_leaves = jax.tree.leaves(ex_big._stores)
    ex_big.run_iteration([], reqs, [])
    assert all(leaf.is_deleted() for leaf in old_leaves), \
        "store donation regressed: inputs survived the decode step"
    temps = {"small": _decode_temp_bytes(ex_small),
             "big": _decode_temp_bytes(ex_big)}
    if temps["big"] is None:  # backend without memory analysis
        pytest.skip("backend reports no memory analysis")
    capacity_delta = store_bytes["big"] - store_bytes["small"]
    temp_growth = temps["big"] - temps["small"]
    assert temp_growth < capacity_delta / 8, \
        (f"temp allocation grew {temp_growth}B across a {capacity_delta}B "
         f"capacity increase — a capacity-shaped copy is back: {temps}")


def test_step_time_independent_of_capacity_with_exact_parity():
    """1x/4x/8x ``num_pages`` at fixed live tokens: medians interleaved
    across capacities must stay within a generous flatness bound (the
    benchmark gates <10%; the test bound only has to catch a return to
    O(capacity), which was >2x per 4x capacity), with bit-exact emitted
    tokens and identical jit keys."""
    base = 36
    runs = {m: _setup(base * m) for m in (1, 4, 8)}
    samples = {m: [] for m in runs}
    for _ in range(15):
        for m, (ex, reqs) in runs.items():
            t0 = time.perf_counter()
            ex.run_iteration([], reqs, [])
            samples[m].append(time.perf_counter() - t0)
            for r in reqs:
                r.decoded += 1
    emitted = {m: {r.rid: list(ex.emitted[r.rid]) for r in reqs}
               for m, (ex, reqs) in runs.items()}
    keys = {m: set(ex.recompile_keys) for m, (ex, _) in runs.items()}
    assert emitted[4] == emitted[1] and emitted[8] == emitted[1], \
        "KV capacity changed emitted tokens"
    assert keys[4] == keys[1] and keys[8] == keys[1], \
        f"KV capacity leaked into jit signatures: {keys}"
    med = {m: statistics.median(s) for m, s in samples.items()}
    ratio = max(med.values()) / min(med.values())
    assert ratio < 2.0, \
        (f"decode step time scales with capacity again: medians "
         f"{ {m: round(v * 1e3, 3) for m, v in med.items()} } ms "
         f"(ratio {ratio:.2f})")


def test_stored_values_identical_across_container_dtypes():
    """The container dtype is backend-dependent (f32 where the backend
    lacks native bf16 scatter), but stored values are rounded through
    bf16 first — so what a reader gets back is bit-identical to a bf16
    container, which is what keeps emitted-token parity exact."""
    import jax.numpy as jnp
    from repro.cache.paged import PagedStackStore
    k = np.random.default_rng(0).normal(size=(2, 4, 2, 8)).astype(np.float32)
    v = np.random.default_rng(1).normal(size=(2, 4, 2, 8)).astype(np.float32)
    bt = jnp.asarray([[0, 1], [2, 3]], jnp.int32)
    start = jnp.zeros((2,), jnp.int32)
    new_lens = jnp.full((2,), 4, jnp.int32)
    out = {}
    for dtype in (jnp.bfloat16, jnp.float32):
        s = PagedStackStore.build(3, 6, 4, 2, 8, dtype=dtype)
        s = s.write_batch(jnp.asarray(k), jnp.asarray(v), bt, start,
                          new_lens, layer=jnp.int32(1))
        ck, cv = s.gather_batch(bt, layer=jnp.int32(1))
        out[str(dtype)] = (np.asarray(ck.astype(jnp.bfloat16), np.float32),
                          np.asarray(cv.astype(jnp.bfloat16), np.float32))
    (ka, va), (kb, vb) = out.values()
    np.testing.assert_array_equal(ka, kb)
    np.testing.assert_array_equal(va, vb)


def test_copy_page_under_flat_layout_copies_every_layer():
    """COW boundary copy: one page id, every layer's row."""
    import jax.numpy as jnp
    from repro.cache.paged import PagedStackStore
    L, ppl = 3, 5
    s = PagedStackStore.build(L, ppl, 4, 2, 8, dtype=jnp.float32)
    vals = jnp.arange(s.k_pages.size, dtype=jnp.float32).reshape(
        s.k_pages.shape)
    s = PagedStackStore(vals, vals + 1.0, L)
    out = s.copy_page(jnp.int32(1), jnp.int32(3))
    for layer in range(L):
        src, dst = layer * ppl + 1, layer * ppl + 3
        np.testing.assert_array_equal(np.asarray(out.k_pages[dst]),
                                      np.asarray(s.k_pages[src]))
        np.testing.assert_array_equal(np.asarray(out.v_pages[dst]),
                                      np.asarray(s.v_pages[src]))
        # untouched rows stay put
        np.testing.assert_array_equal(np.asarray(out.k_pages[src]),
                                      np.asarray(s.k_pages[src]))
