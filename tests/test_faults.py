"""Fault-injected serving tier (ISSUE 6): deterministic FaultPlan,
hardened request lifecycle (terminal FAILED/CANCELLED with exactly-once
resource release), CapacityExceeded livelock guard, encoder-cache
pinning, modality-aware load shedding, and router failover.

The central property: *any* fault schedule — cancels at random stages
(including mid-COW-claim and post-preemption), deadlines, encoder and
executor faults — leaves the allocator invariant-clean with zero leaked
pages and zero leaked encoder-cache pins, and every request in exactly
one terminal state. And an installed-but-empty faults layer changes
nothing at all."""
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import sim_stack_cached
from repro.core.scheduler import make_policy
from repro.serving.encoder_cache import EncoderCache
from repro.serving.engine import Engine, EngineConfig
from repro.serving.executors import SimExecutor, make_cost_model
from repro.serving.faults import CANCEL_STAGES, FaultPlan, FaultRates
from repro.serving.metrics import lifecycle_counts
from repro.serving.request import (TERMINAL_STATES, Modality, Request,
                                   State, VehicleClass)
from repro.serving.router import Router
from repro.serving.workload import WorkloadConfig, generate

POLICY = "tcm"


def _wl(n=40, seed=0, **kw):
    kw.setdefault("duplicate_prob", 0.3)
    kw.setdefault("shared_prefix_prob", 0.3)
    kw.setdefault("rate", 3.0)
    return generate(WorkloadConfig(mix="MH", num_requests=n,
                                   seed=seed, **kw))


def _engine(plan=None, **cfg_kw):
    _ex, classifier, _cfg, _prof, _est = sim_stack_cached()
    cfg_kw.setdefault("kv_pages", 2048)
    cfg_kw.setdefault("token_budget", 512)
    return Engine(make_policy(POLICY), SimExecutor(make_cost_model(
        "llava-7b")), classifier, EngineConfig(**cfg_kw), faults=plan)


def _assert_clean(eng, reqs):
    """Exactly-once release: invariants green, zero leaked pages/pins,
    every request terminal (the partition covers the workload)."""
    eng.allocator.check_invariants()
    assert eng.allocator.used_pages == 0
    if eng.encoder_cache is not None:
        stats = eng.encoder_cache.stats()
        assert stats["pin_refs"] == 0
        assert stats["pinned"] == 0
    assert eng._enc_pins == {}
    counts = lifecycle_counts(reqs)
    assert counts["in_flight"] == 0
    assert (counts["finished"] + counts["rejected"] + counts["failed"]
            + counts["cancelled"]) == len(reqs)
    done = {r.rid for r in eng.finished}
    assert len(done) == len(eng.finished)          # none double-finished
    assert done.isdisjoint(r.rid for r in eng.aborted)
    assert done.isdisjoint(r.rid for r in eng.rejected)


# ---------------- FaultPlan determinism -------------------------------------


def test_fault_plan_replays_identically():
    reqs = _wl(30, seed=3)
    rates = FaultRates(cancel_prob=0.5, deadline_prob=0.5,
                       encoder_fault_prob=0.5, step_fault_prob=0.2)

    def trace(plan):
        out = []
        for r in reqs:
            for stage in CANCEL_STAGES:
                out.append(plan.should_cancel(r, stage))
            out.append(plan.deadline_for(r))
            out.append(plan.encoder_fault(r))
        for it in range(50):
            out.append(plan.step_fault(it, 0))
        return out

    a = trace(FaultPlan(seed=11, rates=rates))
    b = trace(FaultPlan(seed=11, rates=rates))
    assert a == b
    assert trace(FaultPlan(seed=12, rates=rates)) != a


def test_fault_plan_decisions_independent_of_order():
    """Per-request decisions hash content, not arrival order: consulting
    requests in a different order yields the same per-rid outcomes."""
    reqs = _wl(20, seed=4)
    rates = FaultRates(cancel_prob=0.5, deadline_prob=0.5)
    p1, p2 = (FaultPlan(seed=5, rates=rates) for _ in range(2))
    d1 = {r.rid: p1.deadline_for(r) for r in reqs}
    d2 = {r.rid: p2.deadline_for(r) for r in reversed(reqs)}
    assert d1 == d2
    c1 = {r.rid: p1._cancel_point(r.rid) for r in reqs}
    c2 = {r.rid: p2._cancel_point(r.rid) for r in reversed(reqs)}
    assert c1 == c2


def test_explicit_cancel_fires_once_at_nth_observation():
    plan = FaultPlan(cancels={"a": ("running", 2)})
    req = Request(rid="a", modality=Modality.TEXT, arrival=0.0,
                  text_tokens=10, prompt_tokens=10)
    assert not plan.should_cancel(req, "waiting")
    assert not plan.should_cancel(req, "running")    # 1st sighting
    assert plan.should_cancel(req, "running")        # 2nd: fire
    assert not plan.should_cancel(req, "running")    # never again


# ---------------- lifecycle: cancel / deadline / retry ----------------------


def test_cancel_running_request_releases_everything():
    reqs = _wl(12, seed=1)
    victim_rid = reqs[0].rid
    plan = FaultPlan(cancels={victim_rid: ("running", 1)})
    eng = _engine(plan)
    eng.run(reqs)
    victim = next(r for r in reqs if r.rid == victim_rid)
    assert victim.state is State.CANCELLED
    assert victim.error == "client cancel (running)"
    assert victim.finish_time is None and victim.aborted_at is not None
    _assert_clean(eng, reqs)


def test_deadline_expiry_aborts_exactly_once():
    reqs = _wl(12, seed=2)
    # impossible deadline for one request; generous for another
    plan = FaultPlan(deadlines={reqs[3].rid: 1e-6, reqs[4].rid: 1e6})
    eng = _engine(plan)
    eng.run(reqs)
    expired = next(r for r in reqs if r.rid == reqs[3].rid)
    assert expired.state is State.CANCELLED
    assert "deadline" in expired.error
    assert reqs[4].state is State.FINISHED
    _assert_clean(eng, reqs)


def test_transient_encoder_fault_heals_and_finishes():
    reqs = _wl(12, seed=5)
    mm = next(r for r in reqs if r.mm_units > 0)
    plan = FaultPlan(encoder_faults={mm.rid: 2})   # heals on 3rd attempt
    eng = _engine(plan)
    eng.run(reqs)
    assert mm.state is State.FINISHED
    assert mm.encode_faults == 2
    _assert_clean(eng, reqs)


def test_permanent_encoder_fault_fails_terminally():
    reqs = _wl(12, seed=5)
    mm = next(r for r in reqs if r.mm_units > 0)
    plan = FaultPlan(encoder_faults={mm.rid: 10 ** 6})
    eng = _engine(plan)
    eng.run(reqs)
    assert mm.state is State.FAILED
    assert "encoder fault" in mm.error
    assert mm.encode_faults == eng.config.max_encode_retries + 1
    _assert_clean(eng, reqs)


def test_transient_step_fault_retries_and_completes():
    reqs = _wl(10, seed=6)
    plan = FaultPlan(step_faults={2: 1, 5: 2})   # heal within the cap
    eng = _engine(plan)
    eng.run(reqs)
    assert all(r.state is State.FINISHED for r in reqs)
    assert plan.injected["step"] == 3
    _assert_clean(eng, reqs)


def test_permanent_step_fault_fails_the_batch():
    reqs = _wl(10, seed=6)
    plan = FaultPlan(step_faults={3: 10 ** 6})
    eng = _engine(plan)
    eng.run(reqs)
    assert any(r.state is State.FAILED and "executor fault" in r.error
               for r in reqs)
    _assert_clean(eng, reqs)


# ---------------- CapacityExceeded livelock guard (satellite) ---------------


def test_grow_kv_capacity_exceeded_fails_instead_of_livelock():
    """A context that outgrows *total* KV capacity mid-decode (client
    streams longer than declared) must fail with CapacityExceeded — the
    seed's recompute-style self-preemption re-admitted and re-preempted
    it at the same point forever."""
    eng = _engine(None, kv_pages=32)   # 512 tokens total
    req = Request(rid="big", modality=Modality.TEXT, arrival=0.0,
                  text_tokens=200, prompt_tokens=200, output_tokens=8)
    pending = [req]
    for _ in range(20):                # admit + start decoding
        pending = eng.step(pending)
        if req.state is State.RUNNING:
            break
    assert req.state is State.RUNNING
    req.output_tokens = 10_000         # declared 8, streams past capacity
    for _ in range(5_000):
        eng.step(pending)
        if req.state in TERMINAL_STATES:
            break
    assert req.state is State.FAILED
    assert "CapacityExceeded" in req.error
    assert req.preemptions <= 2        # no preemption churn
    _assert_clean(eng, [req])


def test_grow_kv_feasible_growth_never_fails():
    """The guard only fires on impossible contexts: growth that still
    fits total capacity completes (however long the stream ran over its
    declaration), never FAILED."""
    eng = _engine(None, kv_pages=64)   # 1024 tokens total
    req = Request(rid="ok", modality=Modality.TEXT, arrival=0.0,
                  text_tokens=200, prompt_tokens=200, output_tokens=8)
    pending = [req]
    for _ in range(20):
        pending = eng.step(pending)
        if req.state is State.RUNNING:
            break
    assert req.state is State.RUNNING
    req.output_tokens = 700            # 900 total: fits the 1024 pool
    for _ in range(5_000):
        eng.step(pending)
        if req.state in TERMINAL_STATES:
            break
    assert req.state is State.FINISHED
    assert req.decoded == 700
    _assert_clean(eng, [req])


# ---------------- encoder-cache pinning (satellite) -------------------------


def test_encoder_cache_pin_survives_eviction():
    c = EncoderCache(capacity=2)
    c.insert("a", 10)
    c.insert("b", 10)
    c.pin("a")
    c.insert("c", 10)                  # over capacity: must evict b, not a
    assert "a" in c and "b" not in c and "c" in c
    assert c.stats()["pinned"] == 1 and c.stats()["pin_refs"] == 1
    c.pin("a")
    assert c.stats()["pin_refs"] == 2
    c.unpin("a")
    c.unpin("a")
    assert c.stats()["pinned"] == 0 and c.stats()["pin_refs"] == 0
    c.insert("d", 10)                  # a unpinned: evictable again
    assert "a" not in c


def test_engine_pins_encoder_entry_while_encoding():
    """A request mid-encode reserves its hash; a duplicate's entry stays
    resident under LRU churn; pins release at terminal."""
    reqs = _wl(20, seed=7, duplicate_prob=0.6)
    eng = _engine(None, encoder_cache_entries=1)   # maximal churn
    pending = list(reqs)
    saw_pin = False
    for _ in range(100_000):
        pending = eng.step(pending)
        if eng.encoder_cache.stats()["pin_refs"] > 0:
            saw_pin = True
        if len(eng.finished) + len(eng.rejected) + len(eng.aborted) \
                == len(reqs):
            break
    assert saw_pin
    _assert_clean(eng, reqs)


# ---------------- load shedding (satellite of the tentpole) -----------------


def test_load_shed_drops_rocks_never_motorcycles():
    reqs = _wl(60, seed=8, rate=50.0)   # burst arrival: sustained pressure
    eng = _engine(None, kv_pages=700, load_shed=True, shed_after_iters=5,
                  max_num_seqs=128)
    eng.run(reqs)
    shed = [r for r in reqs if r.error is not None
            and r.error.startswith("load shed")]
    assert eng.shed_count == len(shed) > 0
    assert all(r.vclass in (VehicleClass.TRUCK, VehicleClass.CAR)
               for r in shed)
    _assert_clean(eng, reqs)


# ---------------- fault-free parity -----------------------------------------


def test_empty_fault_plan_is_bit_exact_noop():
    def run(plan):
        eng = _engine(plan)
        reqs = _wl(40, seed=9)
        eng.run(reqs)
        return {r.rid: (r.state.value, r.finish_time, r.first_token_time,
                        r.decoded, r.preemptions, r.cached_prefix_tokens)
                for r in reqs}
    assert run(None) == run(FaultPlan())


# ---------------- the chaos property ----------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000),
       cancel=st.floats(0.0, 0.5), deadline=st.floats(0.0, 0.3),
       encoder=st.floats(0.0, 0.5), step=st.floats(0.0, 0.05),
       kv_pages=st.sampled_from([512, 1024, 2048]),
       shed=st.booleans())
def test_any_fault_schedule_conserves_resources(seed, cancel, deadline,
                                                encoder, step, kv_pages,
                                                shed):
    """Whatever the sampled schedule does — cancels at any stage (incl.
    during prefix-cache COW claims and preemption windows), deadlines,
    encoder/executor faults, load shedding — the allocator stays
    invariant-clean (free+owned+cached == num_pages by its own check),
    no page or pin leaks, and the workload partitions into terminal
    states exactly."""
    rates = FaultRates(cancel_prob=cancel, deadline_prob=deadline,
                       encoder_fault_prob=encoder, step_fault_prob=step,
                       deadline_min_s=0.5, deadline_max_s=20.0)
    plan = FaultPlan(seed=seed, rates=rates)
    eng = _engine(plan, kv_pages=kv_pages, load_shed=shed,
                  shed_after_iters=10)
    reqs = _wl(40, seed=seed % 100)
    eng.run(reqs)
    _assert_clean(eng, reqs)


# ---------------- router failover -------------------------------------------


def test_router_failover_none_lost_none_double_finished():
    _ex, classifier, _cfg, _prof, _est = sim_stack_cached()
    cm = make_cost_model("llava-7b")
    plan = FaultPlan(seed=0, replica_kills={0: 4.0})
    router = Router([SimExecutor(cm), SimExecutor(cm)], classifier,
                    EngineConfig(kv_pages=2048, token_budget=512),
                    policy=POLICY, routing="least-loaded", faults=plan)
    reqs = _wl(40, seed=10)
    router.run_stepped(reqs)
    assert not router.alive[0] and router.alive[1]
    assert router.redispatched > 0 and not router.lost
    assert all(r.is_terminal for r in reqs)
    finished = [r.rid for eng in router.engines for r in eng.finished]
    assert len(finished) == len(set(finished))
    # survivors re-ran the dead replica's work from scratch
    assert any(r.redispatches > 0 and r.state is State.FINISHED
               for r in reqs)
    survivor = router.engines[1]
    survivor.allocator.check_invariants()
    assert survivor.allocator.used_pages == 0
    assert survivor.encoder_cache.stats()["pin_refs"] == 0


def test_router_prefix_aware_routing_follows_content():
    """prefix-aware routing sends a duplicate where the pages are: after
    replica 1 finishes a video, a duplicate of the same content routes
    there even if replica 0 is less loaded."""
    _ex, classifier, _cfg, _prof, _est = sim_stack_cached()
    cm = make_cost_model("llava-7b")
    router = Router([SimExecutor(cm), SimExecutor(cm)], classifier,
                    EngineConfig(kv_pages=2048, token_budget=512),
                    policy=POLICY, routing="prefix-aware")
    v1 = Request(rid="v1", modality=Modality.VIDEO, arrival=0.0,
                 text_tokens=32, mm_units=784, prompt_tokens=816,
                 output_tokens=8, mm_hash="vidA")
    v2 = Request(rid="v2", modality=Modality.VIDEO, arrival=0.0,
                 text_tokens=32, mm_units=784, prompt_tokens=816,
                 output_tokens=8, mm_hash="vidA")
    v3 = Request(rid="v3", modality=Modality.VIDEO, arrival=5.0,
                 text_tokens=48, mm_units=784, prompt_tokens=832,
                 output_tokens=8, mm_hash="vidA")
    # v1+v2 run on replica 0 (content turns popular -> chain published);
    # by v3's arrival that replica holds the pages and must attract it
    # even though both replicas carry equal routed load
    router.engines[0].run([v1, v2])
    assert router._route(v3) == 0
    assert router.engines[0].allocator.match_prefix(
        v3.content_chunks(), v3.prompt_tokens - 1).tokens > 0


def test_cancelled_after_prefill_publishes_chain_for_reuse():
    """A cancelled request whose prefill completed leaves re-monetizable
    KV: the published chain serves a later duplicate."""
    a = Request(rid="a", modality=Modality.VIDEO, arrival=0.0,
                text_tokens=32, mm_units=784, prompt_tokens=816,
                output_tokens=500, mm_hash="vidB")
    b = Request(rid="b", modality=Modality.VIDEO, arrival=0.01,
                text_tokens=32, mm_units=784, prompt_tokens=816,
                output_tokens=500, mm_hash="vidB")
    c = Request(rid="c", modality=Modality.VIDEO, arrival=3.0,
                text_tokens=48, mm_units=784, prompt_tokens=832,
                output_tokens=8, mm_hash="vidB")
    plan = FaultPlan(cancels={"a": ("running", 1), "b": ("running", 1)})
    eng = _engine(plan)
    eng.run([a, b, c])
    assert a.state is State.CANCELLED and b.state is State.CANCELLED
    assert c.state is State.FINISHED
    assert c.cached_prefix_tokens > 0   # reclaimed the cancelled chain
    _assert_clean(eng, [a, b, c])


def test_abort_is_idempotent():
    eng = _engine(None)
    req = _wl(5, seed=11)[0]
    pending = [req] + _wl(5, seed=11)[1:]
    pending = eng.step(pending)
    assert eng.cancel(req)
    assert not eng.cancel(req)          # second abort: no-op
    assert not eng._abort(req, State.FAILED, "x")
    assert req.state is State.CANCELLED
    assert len([r for r in eng.aborted if r.rid == req.rid]) == 1
