"""Substrate tests: paged KV store, slot store, checkpointing, data
pipeline, workload generation."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cache.paged import PagedKVStore, SlotStore
from repro.serving.workload import MIXES, WorkloadConfig, generate
from repro.train.checkpoint import load, save
from repro.train.data import PackedTokenDataset

KEY = jax.random.PRNGKey(0)


def test_paged_store_write_gather_roundtrip():
    store = PagedKVStore.create(num_pages=8, page_size=4, kv_heads=2,
                                head_dim=8, dtype=jnp.float32)
    pages = [5, 2, 7]
    k = jax.random.normal(KEY, (10, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(1), (10, 2, 8))
    store = store.write(k[:6], v[:6], pages, start=0)
    store = store.write(k[6:], v[6:], pages, start=6)   # crosses page bound
    kg, vg = store.gather(pages)
    np.testing.assert_allclose(np.asarray(kg[:10]), np.asarray(k), atol=1e-6)
    np.testing.assert_allclose(np.asarray(vg[:10]), np.asarray(v), atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(n_tok=st.integers(1, 16), start=st.integers(0, 15))
def test_paged_store_write_positions_property(n_tok, start):
    """Tokens land at (start+i) within the page sequence regardless of split."""
    store = PagedKVStore.create(num_pages=16, page_size=4, kv_heads=1,
                                head_dim=4, dtype=jnp.float32)
    pages = list(range(8))  # 32 slots
    if start + n_tok > 32:
        n_tok = 32 - start
    k = jnp.arange(n_tok * 4, dtype=jnp.float32).reshape(n_tok, 1, 4) + 100
    store = store.write(k, k, pages, start=start)
    kg, _ = store.gather(pages)
    np.testing.assert_allclose(np.asarray(kg[start:start + n_tok]),
                               np.asarray(k), atol=1e-6)


def test_paged_store_matches_paged_kernel():
    """Engine-level integration: store pages -> Pallas paged kernel == ref."""
    from repro.kernels import ops
    from repro.kernels.ref import ref_paged_attention
    store = PagedKVStore.create(16, 8, 2, 32, dtype=jnp.float32)
    ks = jax.random.split(KEY, 3)
    ctx = 19
    k = jax.random.normal(ks[0], (ctx, 2, 32))
    v = jax.random.normal(ks[1], (ctx, 2, 32))
    pages = [3, 9, 1]
    store = store.write(k, v, pages, start=0)
    q = jax.random.normal(ks[2], (1, 4, 32))
    bt = jnp.array([pages], jnp.int32)
    ln = jnp.array([ctx], jnp.int32)
    out = ops.paged_attention(q, store.k_pages, store.v_pages, bt, ln)
    ref = ref_paged_attention(q, store.k_pages, store.v_pages, bt, ln)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_slot_store_isolation():
    s = SlotStore.create(4, {"ssm": (3, 2)})
    s = s.write(1, {"ssm": jnp.ones((3, 2))})
    s = s.write(2, {"ssm": 2 * jnp.ones((3, 2))})
    assert float(s.read(0)["ssm"].sum()) == 0.0
    assert float(s.read(1)["ssm"].sum()) == 6.0
    assert float(s.read(2)["ssm"].sum()) == 12.0


def test_checkpoint_roundtrip(tmp_path):
    from repro.configs import get_reduced
    from repro.train.loop import make_train_state
    cfg = get_reduced("xlstm-125m")
    state = make_train_state(cfg, KEY)
    path = os.path.join(tmp_path, "ck.npz")
    save(path, state)
    state2 = load(path, jax.tree.map(jnp.zeros_like, state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_deterministic_and_shaped():
    ds = PackedTokenDataset(vocab_size=1000, seq_len=64, seed=3)
    b1 = ds.batch(7, 4)
    b2 = ds.batch(7, 4)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 64)
    # next-token alignment
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    assert b1["tokens"].max() < 1000
    assert b1["tokens"].min() >= 1


@pytest.mark.parametrize("mix", ["T0", "ML", "MH"])
def test_workload_mix_fractions(mix):
    reqs = generate(WorkloadConfig(mix=mix, num_requests=2000, seed=0))
    frac = {m: sum(r.modality.value == m for r in reqs) / len(reqs)
            for m in ["text", "image", "video"]}
    for m, expected in MIXES[mix].items():
        assert abs(frac[m] - expected) < 0.04


def test_workload_orders_of_magnitude():
    """Paper Fig 2: video >> image >> text in prompt tokens (medians)."""
    reqs = generate(WorkloadConfig(mix="MH", num_requests=2000, seed=0))
    med = {m: np.median([r.prompt_tokens for r in reqs
                         if r.modality.value == m])
           for m in ["text", "image", "video"]}
    assert med["video"] > 10 * med["image"] > 10 * med["text"] / 10
    assert med["video"] > 1000
    assert 500 <= med["image"] <= 1000


def test_workload_poisson_rate():
    reqs = generate(WorkloadConfig(mix="MH", rate=4.0, num_requests=4000,
                                   seed=2))
    span = reqs[-1].arrival - reqs[0].arrival
    assert abs(4000 / span - 4.0) < 0.3
