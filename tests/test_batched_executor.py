"""Batched paged-KV execution path: kernel ragged-length coverage and
batched-vs-legacy token-parity (the PR-3 equivalence oracle).

The batched ModelExecutor must emit bit-identical greedy tokens to the
seed's sequential dense-slot path (``legacy=True``) — under packed ragged
prefill, fused decode, preemption/recompute, and engine-driven multimodal
mixes."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cache import BlockAllocator
from repro.serving.executors import ExecutorConfig, ModelExecutor, \
    SlotCapacityError
from repro.serving.request import Modality, Request, State

# ---------------- paged kernel: ragged lengths vs the jnp oracle ------------


def _paged_case(lens, P=8, page=4, KV=2, H=4, hd=32, seed=0):
    import jax
    import jax.numpy as jnp
    B = len(lens)
    max_pages = max(1, -(-max(lens) // page))
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (P, page, KV, hd))
    vp = jax.random.normal(ks[2], (P, page, KV, hd))
    bt = jax.random.randint(ks[3], (B, max_pages), 0, P)
    return q, kp, vp, bt, jnp.asarray(lens, jnp.int32)


@pytest.mark.parametrize("lens", [
    [0],            # empty row: guard must zero the output, not NaN
    [3],            # shorter than one page
    [4], [8],       # exactly at page boundaries
    [32],           # full block table
    [0, 3, 4, 32],  # ragged batch mixing all of the above
])
def test_paged_kernel_ragged_lengths_match_ref(lens):
    from repro.kernels import ops
    from repro.kernels.ref import ref_paged_attention
    q, kp, vp, bt, ln = _paged_case(lens)
    out = ops.paged_attention(q, kp, vp, bt, ln)
    ref = ref_paged_attention(q, kp, vp, bt, ln)
    assert not np.isnan(np.asarray(out)).any()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_paged_kernel_length_zero_row_is_exact_zero():
    from repro.kernels import ops
    from repro.kernels.ref import ref_paged_attention
    q, kp, vp, bt, ln = _paged_case([0, 7])
    out = np.asarray(ops.paged_attention(q, kp, vp, bt, ln))
    ref = np.asarray(ref_paged_attention(q, kp, vp, bt, ln))
    assert (out[0] == 0).all() and (ref[0] == 0).all()
    assert np.abs(out[1]).sum() > 0


def test_ref_paged_prefill_matches_chunked_dense_oracle():
    """Packed ragged prefill oracle == dense chunked-prefill oracle."""
    import jax
    import jax.numpy as jnp
    from repro.cache.paged import PagedKVStore
    from repro.kernels.ref import (ref_paged_prefill_attention,
                                   ref_prefill_attention)
    P, page, KV, H, hd = 12, 4, 2, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    hist, chunk = 9, 6
    k = jax.random.normal(ks[0], (hist + chunk, KV, hd))
    v = jax.random.normal(ks[1], (hist + chunk, KV, hd))
    q = jax.random.normal(ks[2], (1, chunk, H, hd))
    pages = [7, 2, 9, 4]
    store = PagedKVStore.create(P, page, KV, hd, dtype=jnp.float32)
    store = store.write(k, v, pages, start=0)
    bt = jnp.asarray([pages], jnp.int32)
    out = ref_paged_prefill_attention(
        q, store.k_pages, store.v_pages, bt,
        jnp.asarray([hist], jnp.int32), jnp.asarray([chunk], jnp.int32))
    ref = ref_prefill_attention(q, k[None], v[None], q_start=hist)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


# ---------------- executor pair + schedule driver ----------------------------

_EXECUTORS = {}
_RID = [0]


def _executor(legacy: bool) -> ModelExecutor:
    key = "legacy" if legacy else "batched"
    if key not in _EXECUTORS:
        from repro.configs import get_reduced
        _EXECUTORS[key] = ModelExecutor(
            get_reduced("chatglm3-6b"),
            ExecutorConfig(max_slots=8, max_len=256, legacy=legacy))
    return _EXECUTORS[key]


def _mk_req(prompt: int, out: int) -> Request:
    _RID[0] += 1
    return Request(rid=f"pp{_RID[0]}", modality=Modality.TEXT, arrival=0.0,
                   text_tokens=prompt, prompt_tokens=prompt,
                   output_tokens=out)


def _drive(ex: ModelExecutor, specs, chunk: int, preempt_at: int,
           victim_idx: int):
    """Scripted engine-like schedule: chunked prefill + fused decode with
    one recompute-style preemption; returns emitted tokens per request."""
    alloc = BlockAllocator(num_pages=ex.allocator.num_pages, page_size=16)
    ex.bind_allocator(alloc)
    reqs = [_mk_req(p, o) for p, o in specs]
    for r in reqs:
        alloc.allocate(r.rid, r.prompt_tokens + r.output_tokens + 2)
        r.state = State.PREFILLING
    preempted_once = False
    for it in range(200):
        active = [r for r in reqs if r.state in (State.PREFILLING,
                                                 State.RUNNING)]
        if not active:
            break
        if it == preempt_at and not preempted_once:
            v = active[victim_idx % len(active)]
            alloc.free(v.rid)             # engine recompute-style eviction
            v.state = State.PREEMPTED
            ex.release_slot(v)
            v.prefilled = 0
            # immediate re-admission next iteration
            alloc.allocate(v.rid, v.prompt_tokens + v.output_tokens + 2)
            v.state = State.PREFILLING
            preempted_once = True
            continue
        prefill = [(r, min(chunk, r.prompt_tokens - r.prefilled))
                   for r in reqs if r.state is State.PREFILLING]
        decode = [r for r in reqs if r.state is State.RUNNING]
        ex.run_iteration(prefill, decode, [])
        for r, c in prefill:
            r.prefilled += c
            if r.prefilled >= r.prompt_tokens:
                r.state = State.RUNNING
                r.decoded = 1
        for r in decode:
            r.decoded += 1
            if r.decoded >= r.output_tokens:
                r.state = State.FINISHED
                alloc.free(r.rid)
                ex.release_slot(r)
    emitted = {}
    for i, r in enumerate(reqs):
        emitted[i] = list(ex.emitted.get(r.rid, []))
        ex.release_slot(r)      # drop leftover state between examples
        ex.emitted.pop(r.rid, None)
        ex._prompt_cache.pop(r.rid, None)
    return emitted


@settings(max_examples=4, deadline=None, derandomize=True)
@given(p1=st.integers(5, 40), p2=st.integers(5, 40), p3=st.integers(5, 40),
       out=st.integers(2, 5), chunk=st.integers(4, 24),
       preempt_at=st.integers(0, 6), victim=st.integers(0, 2))
def test_batched_matches_legacy_under_random_schedules(
        p1, p2, p3, out, chunk, preempt_at, victim):
    """Property: identical scripted schedules (ragged chunked prefill,
    fused decode, one mid-flight preemption) emit bit-identical greedy
    tokens on the batched and legacy paths."""
    specs = [(p1, out), (p2, out + 1), (p3, out)]
    # rid streams must match pairwise across the two executors
    start = _RID[0]
    got_b = _drive(_executor(False), specs, chunk, preempt_at, victim)
    _RID[0] = start
    got_l = _drive(_executor(True), specs, chunk, preempt_at, victim)
    assert got_b == got_l
    assert all(len(v) >= 1 for v in got_b.values())


def test_over_window_prompts_emit_and_decode_with_parity():
    """Prompts exceeding the context window: the first token is emitted at
    the last in-window chunk and the decode phase still runs real compute
    on both paths (clamped writes), bit-identically."""
    specs = [(300, 3), (270, 2)]
    start = _RID[0]
    got_b = _drive(_executor(False), specs, 64, 999, 0)
    _RID[0] = start
    got_l = _drive(_executor(True), specs, 64, 999, 0)
    assert got_b == got_l
    assert all(len(v) == specs[i][1] for i, v in got_b.items())


def test_page_boundary_prompts_match():
    """Prompts exactly filling their pages: the decode write lands on a
    fresh page (the engine grows coverage at prefill completion)."""
    specs = [(16, 4), (32, 3), (64, 3), (41, 3)]
    start = _RID[0]
    got_b = _drive(_executor(False), specs, 16, 999, 0)
    _RID[0] = start
    got_l = _drive(_executor(True), specs, 16, 999, 0)
    assert got_b == got_l


# ---------------- engine end-to-end parity -----------------------------------

def test_engine_multimodal_mix_token_parity_with_preemptions():
    """Acceptance: run the same multimodal workload through the batched
    and sequential-legacy real executors; every request's emitted token
    stream must match bit-for-bit. The two runs' clocks — and hence
    schedules — legitimately differ, so a recompute-style preemption is
    *injected* deterministically in each run (real-mode wall-clock makes
    organic KV-pressure preemptions timing-dependent)."""
    from repro.core.scheduler import make_policy
    from repro.launch.serve import build_stack
    from repro.serving.engine import Engine
    from repro.serving.workload import WorkloadConfig, generate
    wl = WorkloadConfig(mix="ML", rate=50.0, num_requests=10, seed=7,
                        out_tokens_log_mu=1.8, out_tokens_log_sigma=0.3,
                        text_tokens_log_mu=3.2, text_tokens_log_sigma=0.5,
                        video_frames_min=1, video_frames_max=2,
                        image_patches=32, video_patches_per_frame=16)
    emitted, preempts = {}, {}
    for kind in ("real", "real-legacy"):
        executor, classifier, engine_cfg, _, _ = build_stack(
            "chatglm3-6b", kind, kv_pages=24)
        eng = Engine(make_policy("tcm"), executor, classifier, engine_cfg)
        pending = generate(wl)
        forced = False
        for _ in range(100000):
            pending = eng.step(pending)
            if not forced and eng.running:
                eng._preempt(next(iter(eng.running)))  # mid-decode evict
                forced = True
            if len(eng.finished) + len(eng.rejected) == 10:
                break
        done = eng.finished
        assert len(done) == 10
        emitted[kind] = {r.rid: eng.executor.emitted.get(r.rid)
                         for r in done}
        preempts[kind] = sum(r.preemptions for r in done)
        eng.allocator.check_invariants()
    assert emitted["real"] == emitted["real-legacy"]
    assert all(toks for toks in emitted["real"].values())
    # the injected eviction exercises recompute in both runs
    assert preempts["real"] >= 1 and preempts["real-legacy"] >= 1


def test_kernel_attn_impl_matches_gather_on_decode():
    """attn_impl='kernel' (the TPU serving route, interpret-mode here)
    wires the Pallas paged kernel through the same fused decode step; its
    logits must match the pure-JAX gather path within bf16 tolerance
    (bit-exact token equality is only promised between the batched and
    legacy paths, which share the gather/mha numerics)."""
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.models import transformer as T
    cfg = get_reduced("chatglm3-6b")
    ex = ModelExecutor(cfg, ExecutorConfig(max_slots=2, max_len=64))
    alloc = BlockAllocator(num_pages=ex.allocator.num_pages, page_size=16)
    ex.bind_allocator(alloc)
    reqs = [_mk_req(9, 3), _mk_req(14, 3)]
    for r in reqs:
        alloc.allocate(r.rid, r.prompt_tokens + 8)
        r.state = State.PREFILLING
    ex.run_iteration([(r, r.prompt_tokens) for r in reqs], [], [])
    toks = jnp.asarray([[ex.emitted[r.rid][-1]] for r in reqs], jnp.int32)
    pos = jnp.asarray([[r.prompt_tokens] for r in reqs], jnp.int32)
    bt = jnp.asarray(
        ex._block_table_rows([r.rid for r in reqs], ex.max_pages))
    cache = {"stages": ex._stores, "block_table": bt,
             "lengths": jnp.asarray([ex._ctx[r.rid] for r in reqs],
                                    jnp.int32),
             "new_lens": jnp.ones((2,), jnp.int32)}
    outs = {}
    for impl in ("gather", "kernel"):   # pure call: no donation, same stores
        logits, _, _ = T.forward(ex.params, cfg, toks, positions=pos,
                                 cache=cache, attn_impl=impl)
        outs[impl] = np.asarray(logits, np.float32)
    np.testing.assert_allclose(outs["gather"], outs["kernel"],
                               atol=5e-2, rtol=5e-2)


# ---------------- ragged geometry -------------------------------------------

def test_block_table_width_buckets_to_live_context():
    """Short-context batches compile narrow block tables: the signature's
    page bucket tracks live pages, not the max_len/page_size cap."""
    from repro.configs import get_reduced
    ex = ModelExecutor(get_reduced("chatglm3-6b"),
                       ExecutorConfig(max_slots=4, max_len=256))
    alloc = BlockAllocator(num_pages=ex.allocator.num_pages, page_size=16)
    ex.bind_allocator(alloc)
    reqs = [_mk_req(20, 2), _mk_req(30, 2)]
    for r in reqs:
        alloc.allocate(r.rid, r.prompt_tokens + 8)
        r.state = State.PREFILLING
    ex.run_iteration([(r, r.prompt_tokens) for r in reqs], [], [])
    for r in reqs:
        r.prefilled = r.prompt_tokens
        r.state = State.RUNNING
        r.decoded = 1
    ex.run_iteration([], reqs, [])
    # 30 prompt tokens -> 2 live pages -> bucket 2; cap would be 16
    assert ex.max_pages == 16
    assert ("prefill", 2, 32, 2) in ex.recompile_keys
    assert ("decode", 2, 2) in ex.recompile_keys
    assert len(ex.recompile_keys) <= ex.recompile_bound()


def test_ragged_off_pins_table_at_cap_with_token_parity():
    """ragged=False (the fixed-geometry ablation) always compiles the
    max_pages-wide table and still emits the same tokens."""
    from repro.configs import get_reduced
    cfg = get_reduced("chatglm3-6b")
    specs = [(20, 3), (37, 2)]
    toks = {}
    for ragged in (True, False):
        ex = ModelExecutor(
            cfg, ExecutorConfig(max_slots=4, max_len=256, ragged=ragged))
        start = _RID[0]
        toks[ragged] = _drive(ex, specs, 16, 999, 0)
        _RID[0] = start
        widths = {k[-1] for k in ex.recompile_keys}
        assert widths == ({16} if not ragged else widths - {16})
    assert toks[True] == toks[False]


def test_recompile_bound_is_logarithmic():
    ex = _executor(False)
    # bound is a product of per-axis log factors, far under the naive
    # (batch x chunk x pages) signature space
    assert ex.recompile_bound() <= (
        ex._n_buckets(ex.max_slots) * ex._n_buckets(ex.max_len)
        * ex._n_buckets(ex.max_pages) * 2)
    assert len(ex.recompile_keys) <= ex.recompile_bound()


def test_num_pages_override_decouples_kv_capacity():
    """Explicit num_pages sizes KV independently of max_slots x max_len
    (prefix-cache-heavy configs): admission that overflows the slot
    geometry's default capacity succeeds under the override."""
    from repro.cache import OutOfPages
    from repro.configs import get_reduced
    cfg = get_reduced("chatglm3-6b")
    ex_small = ModelExecutor(cfg, ExecutorConfig(max_slots=2, max_len=64))
    assert ex_small.capacity_pages == 2 * 64 // 16          # 8
    ex_big = ModelExecutor(cfg, ExecutorConfig(max_slots=2, max_len=64, num_pages=48))
    assert ex_big.capacity_pages == 48
    reqs = [_mk_req(60, 2) for _ in range(6)]               # 4 pages each
    with pytest.raises(OutOfPages):
        for r in reqs:
            ex_small.allocator.allocate(r.rid, r.prompt_tokens + 4)
    for r in reqs:
        ex_big.allocator.allocate(r.rid, r.prompt_tokens + 4)
        r.state = State.PREFILLING
    # stores really are sized to the override: a full-pool prefill runs
    ex_big.run_iteration([(r, r.prompt_tokens) for r in reqs], [], [])
    assert all(len(ex_big.emitted[r.rid]) == 1 for r in reqs)


def test_build_stack_plumbs_kv_pages_to_executor():
    from repro.launch.serve import build_stack
    executor, _, engine_cfg, _, _ = build_stack("chatglm3-6b", "real",
                                                kv_pages=24)
    assert executor.capacity_pages == 24
    assert engine_cfg.kv_pages == 24


def test_kernel_attn_impl_matches_gather_on_prefill():
    """attn_impl='kernel' now routes S>1 chunks through the paged-prefill
    flash kernel; end-to-end logits must track the pure-JAX gather path
    within bf16 accumulation noise on a ragged chunk batch, and the
    greedy token at each row's emitting position must agree exactly.
    (Tight kernel-vs-oracle bounds live in tests/test_kernels.py — here
    the numerics pass through two bf16 layers + lm_head, so worst-case
    logit drift is a few e-1 depending on the token stream.)"""
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.models import transformer as T
    cfg = get_reduced("chatglm3-6b")
    ex = ModelExecutor(cfg, ExecutorConfig(max_slots=2, max_len=64))
    alloc = BlockAllocator(num_pages=ex.allocator.num_pages, page_size=16)
    ex.bind_allocator(alloc)
    # fixed rids: prompt streams are rid-seeded, so the comparison must
    # not depend on how many requests earlier tests created
    reqs = [Request(rid=f"kpf{i}", modality=Modality.TEXT, arrival=0.0,
                    text_tokens=p, prompt_tokens=p, output_tokens=3)
            for i, p in enumerate((11, 19))]
    for r in reqs:
        alloc.allocate(r.rid, r.prompt_tokens + 8)
        r.state = State.PREFILLING
    # first chunk in, second chunk is the compared call
    ex.run_iteration([(r, 8) for r in reqs], [], [])
    S = max(r.prompt_tokens - 8 for r in reqs)
    toks = np.zeros((2, S), np.int32)
    pos = np.zeros((2, S), np.int32)
    for i, r in enumerate(reqs):
        n = r.prompt_tokens - 8
        toks[i, :n] = np.asarray(ex._tokens_for(r, 8, n))[0]
        pos[i] = 8 + np.arange(S)
    bt = jnp.asarray(
        ex._block_table_rows([r.rid for r in reqs], 2))
    cache = {"stages": ex._stores, "block_table": bt,
             "lengths": jnp.asarray([8, 8], jnp.int32),
             "new_lens": jnp.asarray(
                 [r.prompt_tokens - 8 for r in reqs], jnp.int32)}
    outs = {}
    for impl in ("gather", "kernel"):   # pure call: no donation
        logits, _, _ = T.forward(ex.params, cfg, jnp.asarray(toks),
                                 positions=jnp.asarray(pos), cache=cache,
                                 attn_impl=impl)
        outs[impl] = np.asarray(logits, np.float32)
    # compare valid chunk positions only: the kernel zeroes padding-query
    # attention outputs while gather computes (discarded) garbage there —
    # the executor's last_pos gather never reads those positions
    for i, r in enumerate(reqs):
        n = r.prompt_tokens - 8
        np.testing.assert_allclose(outs["gather"][i, :n],
                                   outs["kernel"][i, :n],
                                   atol=2.5e-1, rtol=2.5e-1)
        assert (outs["gather"][i, n - 1].argmax()
                == outs["kernel"][i, n - 1].argmax())


# ---------------- gating / satellites ----------------------------------------

def test_unsupported_arch_falls_back_to_legacy():
    from repro.configs import get_reduced
    ex = ModelExecutor(get_reduced("xlstm-125m"),
                       ExecutorConfig(max_slots=2, max_len=64))
    assert ex.legacy and not ex.paged_ok    # SSM state keeps the slot store


def test_acquire_slot_capacity_error_is_clear():
    ex = _executor(True)
    rids = [_mk_req(8, 2) for _ in range(len(ex.free_slots) + 1)]
    taken = []
    try:
        with pytest.raises(SlotCapacityError, match="max_slots"):
            for r in rids:
                ex.acquire_slot(r)
                taken.append(r)
    finally:
        for r in taken + rids:
            ex.release_slot(r)


def test_token_rng_is_process_stable():
    """crc32-seeded prompt streams (abs(hash(rid)) varied across processes
    under PYTHONHASHSEED)."""
    import zlib
    ex = _executor(True)
    req = _mk_req(12, 2)
    toks = np.asarray(ex._tokens_for(req, 0, 12))[0]
    seed = zlib.crc32(req.rid.encode()) & 0x7FFFFFFF
    expect = np.random.default_rng(seed).integers(
        1, ex.cfg.vocab_size, size=12, dtype=np.int64)
    np.testing.assert_array_equal(toks, expect)
    ex._prompt_cache.pop(req.rid, None)


def test_isolated_run_survives_full_page_pool():
    """Admission-time profiling borrows pages from the live pool; a busy
    pool must clamp the measurement (and a *full* pool must fall back to
    the last measured per-token rate) instead of raising OutOfPages."""
    from repro.configs import get_reduced
    ex = ModelExecutor(get_reduced("chatglm3-6b"),
                       ExecutorConfig(max_slots=2, max_len=64))
    page = ex.allocator.page_size
    # leave a single page free: the 60-token profile (4 pages) must clamp
    ex.allocator.allocate("hog", (ex.allocator.num_pages - 1) * page)
    before = ex.allocator.used_pages
    rec = ex.isolated_run(_mk_req(60, 2))
    assert rec.prefill_time > 0
    assert ex.allocator.used_pages == before       # profile pages returned
    # fully occupied: no measurement possible, extrapolate from last rate
    ex.allocator.allocate("hog2", page)
    assert ex.allocator.available_pages == 0
    rec2 = ex.isolated_run(_mk_req(60, 2))
    assert rec2.prefill_time > 0
    assert ex.allocator.used_pages == ex.allocator.num_pages
    ex.allocator.free("hog")
    ex.allocator.free("hog2")
