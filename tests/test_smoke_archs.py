"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates a REDUCED variant of the same family
(2 layers, d_model<=512, <=4 experts) and runs one forward pass and one
train step on CPU, asserting output shapes and absence of NaNs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import transformer as T
from repro.models.params import init_params

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=16):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    kwargs = {}
    if cfg.arch_type == "vlm":
        kwargs["mm_embeds"] = jax.random.normal(
            KEY, (B, min(cfg.mm_tokens, S // 2), cfg.d_model)).astype(cfg.dtype)
        kwargs["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, :, None], (B, S, 3))
    if cfg.is_encoder_decoder:
        kwargs["enc_frames"] = jax.random.normal(
            KEY, (B, cfg.encoder_seq, cfg.d_model)).astype(cfg.dtype)
    return toks, kwargs


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_limits(arch):
    cfg = get_reduced(arch)
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    expected = {
        "deepseek_coder_33b": (62, 7168, 56, 8, 19200, 32256),
        "qwen2_vl_2b": (28, 1536, 12, 2, 8960, 151936),
        "jamba_1_5_large_398b": (72, 8192, 64, 8, 24576, 65536),
        "grok_1_314b": (64, 6144, 48, 8, 32768, 131072),
        "phi3_5_moe_42b": (32, 4096, 32, 8, 6400, 32064),
        "gemma3_27b": (62, 5376, 32, 16, 21504, 262144),
        "chatglm3_6b": (28, 4096, 32, 2, 13696, 65024),
        "xlstm_125m": (12, 768, 4, 4, 0, 50304),
        "qwen1_5_110b": (80, 8192, 64, 8, 49152, 152064),
        "whisper_base": (6, 512, 8, 8, 2048, 51865),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch):
    cfg = get_reduced(arch)
    params = init_params(T.model_decls(cfg), KEY)
    toks, kwargs = _inputs(cfg)
    logits, cache, aux = T.forward(params, cfg, toks, **kwargs)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert cache is None
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    from repro.train.loop import make_train_state, train_step
    cfg = get_reduced(arch)
    state = make_train_state(cfg, KEY)
    toks, kwargs = _inputs(cfg)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if "mm_embeds" in kwargs:
        batch["mm_embeds"] = kwargs["mm_embeds"]
        batch["positions"] = kwargs["positions"]
    if "enc_frames" in kwargs:
        batch["enc_frames"] = kwargs["enc_frames"]
    state2, metrics = train_step(state, batch, cfg)
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually changed
    l0 = jax.tree.leaves(state.params)[0]
    l1 = jax.tree.leaves(state2.params)[0]
    assert not bool(jnp.allclose(l0, l1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_smoke(arch):
    cfg = get_reduced(arch)
    params = init_params(T.model_decls(cfg), KEY)
    toks, kwargs = _inputs(cfg, S=8)
    cache = init_params(T.cache_decls(cfg, 2, 32), KEY)
    _, cache, _ = T.forward(params, cfg, toks, cache=cache, **kwargs)
    pos = jnp.full((2, 1), 8, jnp.int32)
    if cfg.arch_type == "vlm":
        pos = jnp.broadcast_to(pos[..., None], (2, 1, 3))
    nxt = jnp.zeros((2, 1), jnp.int32)
    dec_kwargs = {}
    logits, cache, _ = T.forward(params, cfg, nxt, positions=pos, cache=cache,
                                 q_start=8, **dec_kwargs)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert int(cache["idx"]) == 9
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ["deepseek_coder_33b", "jamba_1_5_large_398b",
                                  "gemma3_27b", "xlstm_125m", "grok_1_314b",
                                  "chatglm3_6b", "whisper_base"])
def test_chunked_prefill_consistency(arch):
    """Chunked prefill + decode must equal the full forward (dropless MoE)."""
    cfg = get_reduced(arch)
    cf = cfg.num_experts / max(cfg.experts_per_token, 1) if cfg.num_experts else 1.0
    cfg = dataclasses.replace(cfg, dtype=jnp.float32, capacity_factor=cf)
    params = init_params(T.model_decls(cfg), KEY)
    B, P = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P + 1), 0, cfg.vocab_size)
    kwargs = {}
    if cfg.is_encoder_decoder:
        kwargs["enc_frames"] = jax.random.normal(
            KEY, (B, cfg.encoder_seq, cfg.d_model)).astype(jnp.float32)
    full, _, _ = T.forward(params, cfg, toks, **kwargs)
    cache = init_params(T.cache_decls(cfg, B, 64, dtype=jnp.float32), KEY)
    _, cache, _ = T.forward(params, cfg, toks[:, :8], cache=cache, q_start=0, **kwargs)
    _, cache, _ = T.forward(params, cfg, toks[:, 8:12], cache=cache, q_start=8, **kwargs)
    lg, _, _ = T.forward(params, cfg, toks[:, 12:13],
                         positions=jnp.full((B, 1), 12), cache=cache, q_start=12)
    err = float(jnp.abs(lg[:, 0] - full[:, 12]).max())
    assert err < 5e-4, f"{arch}: chunked vs full mismatch {err}"
