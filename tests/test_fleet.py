"""Fleet tier + page-chain migration (ISSUE 9): replica lifecycle,
graceful drain, elastic repartitioning, and the chunked KV-transfer
protocol (manifest = trie path, per-page checksums, retry-with-backoff,
fallback to residual re-prefill).

Central properties:
  * with no fleet events scheduled, ``Fleet.run_stepped`` is bit-exact
    with ``Router.run_stepped``;
  * any sampled migration fault schedule conserves pages and pins
    fleet-wide and leaves every request in exactly one terminal state on
    exactly one replica (the hypothesis property);
  * a replica killed mid-ENCODING releases its encoder-cache pin exactly
    once and the request finishes on a survivor (the ``_kill`` fix).
"""
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import sim_stack_cached
from repro.serving.engine import EngineConfig
from repro.serving.executors import SimExecutor, make_cost_model
from repro.serving.faults import FaultPlan, FaultRates
from repro.serving.fleet import Fleet, FleetConfig, ReplicaState
from repro.serving.metrics import lifecycle_counts, summarize_fleet
from repro.serving.migration import (MigrationConfig, PageRecord,
                                     migrate, record_checksum,
                                     simulate_transfer)
from repro.serving.request import Modality, Request, State
from repro.serving.router import Router
from repro.serving.workload import WorkloadConfig, generate

POLICY = "tcm"


def _wl(n=40, seed=0, **kw):
    kw.setdefault("duplicate_prob", 0.3)
    kw.setdefault("shared_prefix_prob", 0.3)
    kw.setdefault("rate", 3.0)
    return generate(WorkloadConfig(mix="MH", num_requests=n,
                                   seed=seed, **kw))


def _mk(cls, n=2, plan=None, routing="least-loaded", cfg_kw=None, **kw):
    _ex, classifier, _cfg, _prof, _est = sim_stack_cached()
    cm = make_cost_model("llava-7b")
    cfg = dict(kv_pages=2048, token_budget=512)
    cfg.update(cfg_kw or {})
    return cls([SimExecutor(cm) for _ in range(n)], classifier,
               EngineConfig(**cfg),
               policy=POLICY, routing=routing, faults=plan, **kw)


def _snapshot(reqs):
    return {r.rid: (r.state.value, r.finish_time, r.first_token_time,
                    r.decoded, r.preemptions, r.cached_prefix_tokens)
            for r in reqs}


def _assert_fleet_clean(router, reqs):
    """Fleet-wide conservation: every engine (alive, drained, or dead)
    audits zero leaked pages and pins; the workload partitions into
    terminal states; no request finishes on two replicas."""
    for eng in router.engines:
        eng.allocator.check_invariants()
        assert eng.allocator.used_pages == 0
        if eng.encoder_cache is not None:
            stats = eng.encoder_cache.stats()
            assert stats["pin_refs"] == 0 and stats["pinned"] == 0
        assert eng._enc_pins == {}
    counts = lifecycle_counts(reqs)
    assert counts["in_flight"] == 0
    assert (counts["finished"] + counts["rejected"] + counts["failed"]
            + counts["cancelled"]) == len(reqs)
    finished = [r.rid for eng in router.engines for r in eng.finished]
    assert len(finished) == len(set(finished))
    assert not router.lost


# ---------------- transfer protocol units ------------------------------------


def _records(n, payload=False):
    return [PageRecord(i, ((f"mm:v{i // 4}", (i % 4) * 16, 16),), 16,
                       bytes(range(16)) if payload else None).seal()
            for i in range(n)]


def test_checksum_covers_identity_and_payload():
    a = _records(1, payload=True)[0]
    b = PageRecord(a.index, a.runs, a.tokens,
                   bytes([a.payload[0] ^ 1]) + a.payload[1:]).seal()
    assert record_checksum(a) == a.checksum
    assert b.checksum != a.checksum           # payload flip changes it
    c = PageRecord(a.index + 1, a.runs, a.tokens, a.payload).seal()
    assert c.checksum != a.checksum           # chain position changes it


def test_clean_transfer_delivers_everything_in_order():
    man = _records(20)
    cfg = MigrationConfig(chunk_pages=8)
    res = simulate_transfer(man, "r1", 10.0, cfg)
    assert res.status == "migrated"
    assert [r.index for r in res.delivered] == list(range(20))
    assert res.retries == 0
    assert res.chunks_sent == 3               # ceil(20 / 8)
    assert res.finish_time > 10.0


def test_transient_faults_retry_then_deliver():
    man = _records(16)
    cfg = MigrationConfig(chunk_pages=8, max_retries=3)
    plan = FaultPlan(migration_faults={("r1", 0): ("timeout", 1),
                                      ("r1", 1): ("corrupt", 2)})
    res = simulate_transfer(man, "r1", 0.0, cfg, plan)
    assert res.status == "migrated"
    assert len(res.delivered) == 16
    assert res.retries == 3
    assert plan.injected["mig_timeout"] == 1
    assert plan.injected["mig_corrupt"] == 2
    # faults cost time: slower than the clean run of the same chain
    clean = simulate_transfer(_records(16), "r1", 0.0, cfg)
    assert res.finish_time > clean.finish_time


def test_permanent_fault_degrades_to_verified_prefix():
    man = _records(24)
    cfg = MigrationConfig(chunk_pages=8, max_retries=2)
    plan = FaultPlan(migration_faults={("r1", 1): ("corrupt", 10 ** 6)})
    res = simulate_transfer(man, "r1", 0.0, cfg, plan)
    assert res.status == "fallback"
    assert [r.index for r in res.delivered] == list(range(8))  # chunk 0
    assert res.retries == cfg.max_retries + 1


def test_source_death_keeps_verified_prefix_target_death_aborts():
    man = _records(24)
    cfg = MigrationConfig(chunk_pages=8, chunk_latency_s=1.0,
                          bandwidth_pages_per_s=8.0)   # 2s per chunk
    res = simulate_transfer(man, "r1", 0.0, cfg, src_kill=3.0)
    assert res.status == "aborted_source_dead"
    assert len(res.delivered) == 8            # one chunk landed before 3s
    res2 = simulate_transfer(man, "r1", 0.0, cfg, dst_kill=3.0)
    assert res2.status == "aborted_target_dead"
    assert len(res2.delivered) == 8           # delivered but never applied


def test_corrupt_chunk_never_installs():
    """A corrupted record's checksum genuinely fails verification — the
    chunk is re-requested, not installed."""
    man = _records(8, payload=True)
    plan = FaultPlan(migration_faults={("r1", 0): ("corrupt", 1)})
    res = simulate_transfer(man, "r1", 0.0, MigrationConfig(), plan)
    assert res.status == "migrated"
    for rec in res.delivered:                 # retry delivered clean copies
        assert record_checksum(rec) == rec.checksum


# ---------------- end-to-end migration between sim engines -------------------


def _video(rid, arrival=0.0, mm_hash=None, out=8):
    return Request(rid=rid, modality=Modality.VIDEO, arrival=arrival,
                   text_tokens=32, mm_units=784, prompt_tokens=816,
                   output_tokens=out, mm_hash=mm_hash or f"vid-{rid}")


def test_migrate_moves_chain_and_finishes_on_target():
    router = _mk(Router, n=2)
    src, dst = router.engines
    req = _video("m1", out=64)
    pending = [req]
    for _ in range(200):
        pending = src.step(pending)
        if req.state is State.RUNNING:
            break
    assert req.state is State.RUNNING and req.prefilled == 816
    res = migrate(src, dst, req, src.now, MigrationConfig())
    assert res.status == "migrated"
    # 784 mm tokens = 49 full shareable pages; the txt!rid tail is private
    assert res.pages_imported == 49
    assert req.ready_floor == res.finish_time > 0.0
    assert req.migrations == 1 and req.redispatches == 1
    # source fully released, exactly once
    src.allocator.check_invariants()
    assert src.allocator.used_pages == 0
    assert src._enc_pins == {}
    # target holds the chain as cached/evictable content until claimed
    dst.allocator.check_invariants()
    assert dst.allocator.prefix_stats()["imported_pages"] == 49
    assert dst.allocator.used_pages == 0
    remaining = [req]
    for _ in range(2000):
        remaining = dst.step(remaining)
        if req.is_terminal:
            break
    assert req.state is State.FINISHED
    assert req.cached_prefix_tokens >= 49 * 16   # re-claimed the chain
    assert req.first_token_time >= res.finish_time  # transfer hold held
    _assert_fleet_clean(router, [req])


def test_migrate_fallback_still_finishes_correctly():
    """Retries exhausted on chunk 0: nothing transfers, the request
    redispatches plainly and re-prefills everything on the target —
    correctness preserved, only latency paid."""
    plan = FaultPlan(migration_faults={
        ("m2", c): ("timeout", 10 ** 6) for c in range(16)})
    router = _mk(Router, n=2, plan=None)
    src, dst = router.engines
    req = _video("m2")
    pending = [req]
    for _ in range(200):
        pending = src.step(pending)
        if req.state is State.RUNNING:
            break
    res = migrate(src, dst, req, src.now, MigrationConfig(), plan)
    assert res.status == "fallback" and not res.delivered
    assert req.ready_floor == 0.0 and req.migrations == 0
    assert src.allocator.used_pages == 0
    remaining = [req]
    for _ in range(2000):
        remaining = dst.step(remaining)
        if req.is_terminal:
            break
    assert req.state is State.FINISHED
    assert req.cached_prefix_tokens == 0      # honest full re-prefill
    _assert_fleet_clean(router, [req])


def test_migrate_dedups_against_target_cache():
    """Target already serves the same video: the chain positions dedup
    against its trie instead of double-allocating."""
    router = _mk(Router, n=2)
    src, dst = router.engines
    # two duplicates make the content popular enough to publish its chain
    a1 = _video("d1", mm_hash="shared-vid")
    a2 = _video("d1b", arrival=0.01, mm_hash="shared-vid")
    dst.run([a1, a2])
    assert a1.state is State.FINISHED
    assert dst.allocator.prefix_stats()["cached_pages"] >= 49
    b = _video("d2", mm_hash="shared-vid")
    pending = [b]
    for _ in range(200):
        pending = src.step(pending)
        if b.state is State.RUNNING:
            break
    res = migrate(src, dst, b, src.now, MigrationConfig())
    assert res.status == "migrated"
    assert res.pages_deduped == 49 and res.pages_imported == 0


# ---------------- satellite: ENCODING-kill pin release -----------------------


def test_kill_during_encoding_releases_pin_once_and_fails_over():
    # small encode budget: the 784-unit video stays ENCODING across steps
    router = _mk(Router, n=2, cfg_kw=dict(encode_budget=64))
    eng = router.engines[0]
    req = _video("enc1", out=8)
    remaining = [[req], []]
    router._assigned[0].append(req)
    for _ in range(100):
        remaining[0] = eng.step(remaining[0])
        if req.state is State.ENCODING:
            break
    assert req.state is State.ENCODING
    assert eng.encoder_cache.stats()["pin_refs"] == 1
    assert req.rid in eng._enc_pins
    router._kill(0, remaining)
    # the dead replica's encoder pin was released exactly once
    assert eng.encoder_cache.stats()["pin_refs"] == 0
    assert eng.encoder_cache.stats()["pinned"] == 0
    assert eng._enc_pins == {}
    eng.allocator.check_invariants()
    assert eng.allocator.used_pages == 0
    # and the request restarts (and re-pins) on the survivor
    assert req in remaining[1] and req.state is State.WAITING
    survivor = router.engines[1]
    for _ in range(2000):
        remaining[1] = survivor.step(remaining[1])
        if req.is_terminal:
            break
    assert req.state is State.FINISHED
    assert survivor.encoder_cache.stats()["pin_refs"] == 0
    _assert_fleet_clean(router, [req])


# ---------------- fleet: bit-exactness, drains, elastic ----------------------


def test_fleet_no_events_bit_exact_with_router():
    for routing in ("least-loaded", "round-robin", "truck-isolation"):
        reqs_a = _wl(40, seed=21)
        reqs_b = _wl(40, seed=21)
        base = _mk(Router, n=3, routing=routing)
        base.run_stepped(reqs_a)
        fleet = _mk(Fleet, n=3, routing=routing, fleet=FleetConfig())
        fleet.run_stepped(reqs_b)
        assert _snapshot(reqs_a) == _snapshot(reqs_b), routing
        # and per-replica placement matched too
        for ea, eb in zip(base.engines, fleet.engines):
            assert {r.rid for r in ea.finished} == \
                {r.rid for r in eb.finished}


def test_drain_migrates_queue_and_finishes_decodes_in_place():
    fleet = _mk(Fleet, n=3, fleet=FleetConfig(drains={0: 3.0}))
    reqs = _wl(40, seed=22)
    fleet.run_stepped(reqs)
    assert fleet.replica_state[0] is ReplicaState.DEAD
    assert not fleet.alive[0]
    assert len(fleet.drain_events) == 1
    ev = fleet.drain_events[0]
    assert ev["replica"] == 0 and ev["duration"] >= 0.0
    assert fleet.migrations_attempted + ev["migrated"] >= 0
    # drained replica kept its decodes: it finished some work itself
    _assert_fleet_clean(fleet, reqs)
    fs = summarize_fleet(fleet)
    assert fs["replicas"][0]["state"] == "dead"
    assert fs["migrations"]["attempted"] == fleet.migrations_attempted


def test_elastic_repartitions_under_mix_shift():
    """Truck-heavy first half, text-only second half: the heavy group
    must shrink (at least one repartition event) and everything still
    completes cleanly."""
    p1 = generate(WorkloadConfig(mix="LCV", num_requests=30, seed=23,
                                 rate=4.0))
    p2 = generate(WorkloadConfig(mix="T0", num_requests=60, seed=24,
                                 rate=8.0))
    off = max(r.arrival for r in p1) + 1.0
    for r in p2:
        r.rid = "p2" + r.rid
        r._chunks_cache = None
        r.arrival += off
    reqs = sorted(p1 + p2, key=lambda r: r.arrival)
    fleet = _mk(Fleet, n=4, routing="elastic",
                truck_replicas=2,
                fleet=FleetConfig(elastic_window=16, elastic_persist=4,
                                  elastic_dwell_s=1.0))
    fleet.run_stepped(reqs)
    assert fleet.repartition_events
    assert any(ev["direction"] == "shrink"
               for ev in fleet.repartition_events)
    _assert_fleet_clean(fleet, reqs)


# ---------------- the fleet chaos property (satellite) -----------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000),
       mig_timeout=st.floats(0.0, 0.4), mig_corrupt=st.floats(0.0, 0.4),
       kill_t=st.floats(0.0, 15.0),     # < 1.0 means "no kill"
       drain_t=st.floats(1.0, 15.0),
       n_replicas=st.sampled_from([2, 3, 4]))
def test_any_migration_fault_schedule_conserves_fleet_resources(
        seed, mig_timeout, mig_corrupt, kill_t, drain_t, n_replicas):
    """Whatever the sampled schedule does — chunk timeouts/corruptions at
    any rate, a drain, an optional kill racing the drain's transfers —
    pages and pins are conserved on every replica and each request lands
    in exactly one terminal state on exactly one replica."""
    rates = FaultRates(migration_timeout_prob=mig_timeout,
                       migration_corrupt_prob=mig_corrupt)
    # keep at least one untouched survivor: a schedule that removes the
    # whole fleet trivially loses requests (covered elsewhere)
    kills = ({n_replicas - 1: kill_t}
             if kill_t >= 1.0 and n_replicas > 2 else {})
    plan = FaultPlan(seed=seed, rates=rates, replica_kills=kills)
    fleet = _mk(Fleet, n=n_replicas, plan=plan,
                fleet=FleetConfig(
                    drains={0: drain_t},
                    migration=MigrationConfig(max_retries=2)))
    reqs = _wl(40, seed=seed % 100)
    fleet.run_stepped(reqs)
    _assert_fleet_clean(fleet, reqs)
    assert fleet.replica_state[0] is ReplicaState.DEAD
