"""Unit + property tests for the TCM core (the paper's contribution)."""
import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.classifier import NaiveClassifier, SmartClassifier
from repro.core.estimator import ImpactEstimator, fit_linreg, fit_quantile
from repro.core.profiler import WorkloadProfiler
from repro.core.regulator import PriorityRegulator
from repro.core.scheduler import make_policy
from repro.serving.executors import SimExecutor, make_cost_model
from repro.serving.request import Modality, Request, VehicleClass
from repro.serving.workload import profiling_workload


@pytest.fixture(scope="module")
def trained():
    ex = SimExecutor(make_cost_model("llava-7b"))
    profile = WorkloadProfiler(ex, "llava-7b").build(profiling_workload())
    est = ImpactEstimator.train(profile)
    smart = SmartClassifier.train(est, profile)
    return ex, profile, est, smart


# ---------------- regulator -------------------------------------------------

def test_regulator_paper_constants():
    reg = PriorityRegulator()
    assert reg.params[VehicleClass.MOTORCYCLE] == dict(static=0.10, k=0.05, p=3.5)
    assert reg.params[VehicleClass.CAR] == dict(static=0.05, k=0.003, p=2.5)
    assert reg.params[VehicleClass.TRUCK] == dict(static=0.00, k=0.00075, p=1.1)


@settings(max_examples=50, deadline=None)
@given(w1=st.floats(0, 1000), dw=st.floats(0.001, 1000))
def test_priority_monotone_in_wait(w1, dw):
    """Aging: priority strictly non-decreasing in waiting time, all classes."""
    reg = PriorityRegulator()
    for v in VehicleClass:
        assert reg.priority(v, w1 + dw) >= reg.priority(v, w1) - 1e-12


@settings(max_examples=30, deadline=None)
@given(w=st.floats(0, 300))
def test_class_hierarchy_preserved_under_equal_wait(w):
    """At equal waiting time, motorcycles >= cars >= trucks priority."""
    reg = PriorityRegulator()
    pm = reg.priority(VehicleClass.MOTORCYCLE, w)
    pc = reg.priority(VehicleClass.CAR, w)
    pt = reg.priority(VehicleClass.TRUCK, w)
    assert pm >= pc >= pt


def test_priority_bounded_and_score_finite():
    reg = PriorityRegulator()
    for v in VehicleClass:
        for w in [0.0, 1.0, 60.0, 3600.0]:
            p = reg.priority(v, w)
            assert 0.0 <= p <= 1.1 + 1e-9
            assert math.isfinite(reg.score(v, w))


def test_truck_eventually_outranks_fresh_motorcycle():
    """No starvation: an old-enough truck beats a fresh motorcycle."""
    reg = PriorityRegulator()
    fresh_m = reg.score(VehicleClass.MOTORCYCLE, 0.0)
    old_t = reg.score(VehicleClass.TRUCK, 3600.0)
    assert old_t < fresh_m  # lower score = earlier


# ---------------- estimator -------------------------------------------------

def test_linreg_exact_on_linear_data():
    X = np.array([[10., 0.], [100., 0.], [1000., 0.], [5000., 0.]])
    y = 0.003 + 1e-4 * X[:, 0]
    w = fit_linreg(X, y)
    np.testing.assert_allclose(w, [0.003, 1e-4, 0.0], atol=1e-8)


def test_quantile_regression_overestimates_median():
    """q=0.9 fit sits above ~90% of noisy samples (paper's SLO protection)."""
    rng = np.random.default_rng(0)
    X = np.stack([rng.uniform(100, 10000, 400), np.zeros(400)], 1)
    y = 1e-4 * X[:, 0] + rng.exponential(0.05, 400)
    w = fit_quantile(X, y, q=0.9)
    pred = np.concatenate([np.ones((400, 1)), X], 1) @ w
    frac_below = (y <= pred).mean()
    assert 0.80 <= frac_below <= 0.98


def test_estimator_accuracy_ms_scale(trained):
    _, profile, est, _ = trained
    errs = est.errors(profile)
    assert errs["text"].mean() < 0.005          # < 5 ms
    assert errs["image"].mean() < 0.05
    assert errs["video"].mean() < 0.08          # seconds-scale TTFTs, ms err


def test_estimator_kv_prediction(trained):
    _, _, est, _ = trained
    _, kv = est.predict("video", 50, 196 * 32)
    assert abs(kv - (50 + 196 * 32)) / (50 + 196 * 32) < 0.05


# ---------------- classifier -----------------------------------------------

def test_smart_classifier_separates_modalities(trained):
    _, _, _, smart = trained
    m, _, _ = smart.classify("text", 100, 0)
    t, _, _ = smart.classify("video", 50, 196 * 64)
    assert m == VehicleClass.MOTORCYCLE
    assert t == VehicleClass.TRUCK


def test_smart_classifier_resource_aware_not_modality_locked(trained):
    """Long text ~ car; image and tiny video land in the same class — the
    paper's motivation for resource-aware (not modality) classification."""
    _, _, _, smart = trained
    long_text, _, _ = smart.classify("text", 9000, 0)
    assert long_text != VehicleClass.MOTORCYCLE
    img, _, _ = smart.classify("image", 50, 576)
    tiny_vid, _, _ = smart.classify("video", 50, 196 * 8)
    assert img == tiny_vid


def test_naive_classifier_is_modality_map(trained):
    _, _, est, _ = trained
    nv = NaiveClassifier(est)
    assert nv.classify("text", 9999, 0)[0] == VehicleClass.MOTORCYCLE
    assert nv.classify("video", 1, 196)[0] == VehicleClass.TRUCK


# ---------------- policies --------------------------------------------------

def _mk(rid, arrival, vclass, slo=10.0, enq=None):
    r = Request(rid=rid, modality=Modality.TEXT, arrival=arrival,
                text_tokens=10, prompt_tokens=10)
    r.vclass = vclass
    r.slo = slo
    r.enqueue_time = arrival if enq is None else enq
    return r


def test_fcfs_orders_by_arrival():
    pol = make_policy("fcfs")
    rs = [_mk("a", 3, VehicleClass.TRUCK), _mk("b", 1, VehicleClass.CAR),
          _mk("c", 2, VehicleClass.MOTORCYCLE)]
    assert [r.rid for r in pol.order(rs, 10)] == ["b", "c", "a"]


def test_edf_orders_by_deadline():
    pol = make_policy("edf")
    rs = [_mk("a", 0, VehicleClass.TRUCK, slo=100),
          _mk("b", 5, VehicleClass.CAR, slo=1),
          _mk("c", 2, VehicleClass.MOTORCYCLE, slo=50)]
    assert [r.rid for r in pol.order(rs, 10)] == ["b", "c", "a"]


def test_tcm_motorcycles_first_then_aging():
    pol = make_policy("tcm")
    now = 100.0
    m_new = _mk("m", 99.9, VehicleClass.MOTORCYCLE)
    t_new = _mk("t", 99.9, VehicleClass.TRUCK)
    t_old = _mk("T", 0.0, VehicleClass.TRUCK)
    order = [r.rid for r in pol.order([t_new, m_new, t_old], now)]
    assert order[0] in ("m", "T")       # aged truck can outrank
    assert order[-1] == "t"             # fresh truck always last


def test_tcm_never_picks_motorcycle_victim():
    pol = make_policy("tcm")
    running = [_mk("m", 0, VehicleClass.MOTORCYCLE),
               _mk("c", 1, VehicleClass.CAR),
               _mk("t", 2, VehicleClass.TRUCK)]
    v = pol.pick_victim(running, 10.0)
    assert v.rid in ("c", "t")
    only_m = [_mk("m1", 0, VehicleClass.MOTORCYCLE)]
    assert pol.pick_victim(only_m, 10.0) is None


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_tcm_order_is_total_and_stable(seed):
    """Ordering never drops/duplicates requests (engine invariant)."""
    rng = np.random.default_rng(seed)
    pol = make_policy("tcm")
    rs = [_mk(f"r{i}", float(rng.uniform(0, 50)),
              list(VehicleClass)[int(rng.integers(3))])
          for i in range(20)]
    out = pol.order(rs, 60.0)
    assert sorted(r.rid for r in out) == sorted(r.rid for r in rs)
