"""ExecutorConfig: the validated construction surface for ModelExecutor.

Covers field validation, the single-point num_pages resolution (the
constructor and ``build_stack`` previously each re-derived the slot-
geometry default), and the post-deprecation removal of the old
bare-kwarg construction (now a ``TypeError`` with the migration path)."""
import pytest

from repro.serving.executors import ExecutorConfig, ModelExecutor


def _cfg():
    from repro.configs import get_reduced
    return get_reduced("chatglm3-6b")


# ---------------- validation -------------------------------------------------

@pytest.mark.parametrize("bad", [
    dict(max_slots=0),
    dict(max_len=0),
    dict(page_size=0),
    dict(num_pages=0),
    dict(num_pages=-4),
    dict(attn_impl="pallas"),
])
def test_invalid_fields_rejected_at_construction(bad):
    with pytest.raises(ValueError):
        ExecutorConfig(**bad)


def test_unknown_field_rejected():
    with pytest.raises(TypeError):
        ExecutorConfig(pages=8)


# ---------------- resolution -------------------------------------------------

def test_resolved_fills_slot_geometry_default():
    cfg = ExecutorConfig(max_slots=4, max_len=128, page_size=16)
    assert cfg.num_pages is None
    r = cfg.resolved()
    assert r.num_pages == 4 * 128 // 16 == cfg.default_num_pages
    # idempotent, and an explicit override is left alone
    assert r.resolved() is r
    assert ExecutorConfig(num_pages=7).resolved().num_pages == 7


def test_executor_allocator_sized_by_resolved_config():
    ex = ModelExecutor(_cfg(), ExecutorConfig(max_slots=2, max_len=64))
    assert ex.capacity_pages == ex.config.num_pages == 2 * 64 // 16
    assert ex.config.num_pages is not None   # executor holds the resolved cfg


def test_build_stack_and_executor_agree_without_explicit_kv_pages():
    """The dedup guarantee: with kv_pages unset, the engine's KV capacity
    comes from the same ExecutorConfig.resolved() call that sized the
    executor's stores — agreement by construction, not by parallel
    derivation."""
    from repro.launch.serve import build_stack
    executor, _, engine_cfg, _, _ = build_stack("chatglm3-6b", "real")
    assert engine_cfg.kv_pages == executor.capacity_pages
    assert engine_cfg.kv_pages == executor.config.num_pages


# ---------------- bare-kwargs removal (post-deprecation) ---------------------

def test_bare_kwargs_removed_raises_with_migration_path():
    """The PR 7 one-release deprecation window is over: bare-kwargs
    construction now fails loudly, and the message spells out the
    ExecutorConfig call to write instead."""
    with pytest.raises(TypeError, match=r"ExecutorConfig\(.*max_slots"):
        ModelExecutor(_cfg(), max_slots=2, max_len=64, num_pages=24)


def test_config_path_emits_no_deprecation_warning():
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        ModelExecutor(_cfg(), ExecutorConfig(max_slots=2, max_len=64))


def test_config_and_kwargs_together_rejected():
    with pytest.raises(TypeError, match="keyword arguments"):
        ModelExecutor(_cfg(), ExecutorConfig(max_slots=2, max_len=64),
                      max_slots=4)
