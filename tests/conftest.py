"""Test configuration.

Provides the shared sim-stack cache (one expensive profiler/estimator
build per test session, usable both as the ``sim_stack`` fixture and —
for ``@given`` tests, which the shim below runs without fixture support —
via the plain ``sim_stack_cached()`` helper), plus a minimal
seeded-random fallback for ``hypothesis`` when the real package is
absent, covering exactly the API surface these tests use (``given``,
``settings``, and the ``strategies`` constructors). When the real
hypothesis is installed it is used unchanged.
"""
import sys

import pytest

_SIM_STACK = None


def sim_stack_cached():
    """(executor, classifier, engine_cfg, profile, estimator), built once."""
    global _SIM_STACK
    if _SIM_STACK is None:
        from repro.launch.serve import build_stack
        _SIM_STACK = build_stack("chatglm3-6b", "sim",
                                 model_preset="llava-7b")
    return _SIM_STACK


@pytest.fixture(scope="session")
def sim_stack():
    return sim_stack_cached()

try:
    import hypothesis  # noqa: F401  (real package wins when available)
except ModuleNotFoundError:
    import types
    import zlib

    import numpy as np

    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        """A draw function over a seeded numpy Generator."""

        def __init__(self, draw):
            self.draw = draw

    def floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    def integers(min_value=0, max_value=1 << 30):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    def sampled_from(options):
        opts = list(options)
        return _Strategy(lambda rng: opts[int(rng.integers(len(opts)))])

    def lists(elements, min_size=0, max_size=10, **_):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(draw)

    def tuples(*elements):
        return _Strategy(lambda rng: tuple(e.draw(rng) for e in elements))

    def given(**strategy_kwargs):
        def decorate(fn):
            # Zero-arg runner so pytest does not mistake the strategy
            # parameter names for fixtures.
            def runner():
                n = getattr(runner, "_max_examples", _DEFAULT_MAX_EXAMPLES)
                base = zlib.crc32(fn.__qualname__.encode())
                for i in range(n):
                    rng = np.random.default_rng((base + i) % (1 << 32))
                    fn(**{k: s.draw(rng)
                          for k, s in strategy_kwargs.items()})
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner
        return decorate

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_):
        def decorate(fn):
            fn._max_examples = max_examples
            return fn
        return decorate

    _shim = types.ModuleType("hypothesis")
    _shim.given = given
    _shim.settings = settings
    _shim.__is_repro_shim__ = True
    _st = types.ModuleType("hypothesis.strategies")
    for _f in (floats, integers, booleans, sampled_from, lists, tuples):
        setattr(_st, _f.__name__, _f)
    _shim.strategies = _st
    sys.modules["hypothesis"] = _shim
    sys.modules["hypothesis.strategies"] = _st
