"""Crash recovery (ISSUE 10): lifecycle journal + replay oracle, replica
restart & rejoin, health-scored auto-drain.

Central properties:
  * the journal is a pure recording — a journal-enabled event-free fleet
    run is bit-identical to the plain router;
  * replaying a replica's journal reconstructs its live accounting
    bit-exactly (terminal states, owned pages, encoder pins) — a second
    independent oracle, checked at every kill/drain and end-of-run;
  * killed/drained replicas restart on schedule, rejoin after the
    warm-up gate, and the kill schedule never re-fires on the fresh
    engine; a whole-fleet outage with an armed restart loses nothing;
  * any sampled restart schedule x fault plan x drain/kill race
    conserves pages and pins fleet-wide (retired engines included) and
    leaves every request in exactly one terminal state on exactly one
    replica (the hypothesis property).
"""
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import sim_stack_cached
from repro.serving.engine import EngineConfig
from repro.serving.executors import SimExecutor, make_cost_model
from repro.serving.faults import FaultPlan
from repro.serving.fleet import Fleet, FleetConfig, ReplicaState
from repro.serving.journal import Journal, replay, verify_engine
from repro.serving.metrics import lifecycle_counts, summarize_fleet
from repro.serving.request import State
from repro.serving.router import Router
from repro.serving.workload import WorkloadConfig, generate

POLICY = "tcm"


def _wl(n=40, seed=0, **kw):
    kw.setdefault("duplicate_prob", 0.3)
    kw.setdefault("shared_prefix_prob", 0.3)
    kw.setdefault("rate", 3.0)
    return generate(WorkloadConfig(mix="MH", num_requests=n,
                                   seed=seed, **kw))


def _mk(cls, n=2, plan=None, routing="least-loaded", cfg_kw=None, **kw):
    _ex, classifier, _cfg, _prof, _est = sim_stack_cached()
    cm = make_cost_model("llava-7b")
    cfg = dict(kv_pages=2048, token_budget=512)
    cfg.update(cfg_kw or {})
    return cls([SimExecutor(cm) for _ in range(n)], classifier,
               EngineConfig(**cfg),
               policy=POLICY, routing=routing, faults=plan, **kw)


def _snapshot(reqs):
    return {r.rid: (r.state.value, r.finish_time, r.first_token_time,
                    r.decoded, r.preemptions, r.cached_prefix_tokens)
            for r in reqs}


def _assert_recovery_clean(fleet, reqs):
    """Fleet-wide conservation including retired (pre-restart) engines,
    plus the journal-replay identity on every engine that ever served."""
    engines = list(fleet.engines) + [e for _i, e in fleet.retired]
    for eng in engines:
        eng.allocator.check_invariants()
        assert eng.allocator.used_pages == 0
        if eng.encoder_cache is not None:
            stats = eng.encoder_cache.stats()
            assert stats["pin_refs"] == 0 and stats["pinned"] == 0
        assert eng._enc_pins == {}
    counts = lifecycle_counts(reqs)
    assert counts["in_flight"] == 0
    assert (counts["finished"] + counts["rejected"] + counts["failed"]
            + counts["cancelled"]) == len(reqs)
    finished = [r.rid for eng in engines for r in eng.finished]
    assert len(finished) == len(set(finished))
    assert not fleet.lost
    assert not fleet._orphans
    assert fleet.verify_journals() == []


# ---------------- journal + replay oracle units ------------------------------


def test_journal_replay_folds_lifecycle():
    j = Journal()
    j.record(0.0, "pin", "a", "h1")
    j.record(0.0, "state", "a", "encoding")
    j.record(1.0, "state", "a", "waiting")
    j.record(1.0, "unpin", "a", "h1")
    j.record(2.0, "acquire", "a", (1, 2))
    j.record(2.5, "acquire", "a", (3,))
    j.record(3.0, "state", "a", "running")
    st_ = replay(j.records)
    assert st_.inflight == {"a"}                  # ingested, not terminal
    assert st_.owned == {"a": [1, 2, 3]}          # acquires accumulate
    assert st_.pins == {}                         # pin released exactly once
    assert st_.stage["a"] == "running"
    j.record(4.0, "release", "a")
    j.record(4.0, "terminal", "a", "finished")
    st2 = replay(j.records)
    assert st2.terminal == {"a": "finished"}
    assert st2.owned == {} and st2.inflight == set()


def test_journal_export_then_reingest_same_engine():
    """An exported rid leaves the in-flight set; a later re-ingest on the
    same engine (failback) re-enters it — the export mark is per-episode,
    not forever."""
    j = Journal()
    j.record(0.0, "state", "b", "waiting")
    j.record(1.0, "release", "b")
    j.record(1.0, "export", "b")
    st1 = replay(j.records)
    assert st1.inflight == set() and "b" in st1.exported
    j.record(2.0, "state", "b", "waiting")
    st2 = replay(j.records)
    assert "b" not in st2.exported and st2.inflight == {"b"}


def test_verify_engine_catches_tampering():
    """The oracle is not a rubber stamp: a forged journal record that the
    live allocator never saw is reported as a mismatch."""
    router = _mk(Router, n=1, cfg_kw=dict(journal=True))
    reqs = _wl(10, seed=3)
    router.run_stepped(reqs)
    eng = router.engines[0]
    assert verify_engine(eng) == []
    eng.journal.record(eng.now, "acquire", "ghost", (1, 2, 3))
    msgs = verify_engine(eng)
    assert msgs and any("ghost" in m for m in msgs)


def test_journal_recording_is_bit_exact():
    """Journal on vs journal off: identical timelines (the journal is
    observation, never perturbation), and every replay agrees."""
    a, b = _wl(40, seed=11), _wl(40, seed=11)
    base = _mk(Router, n=2)
    base.run_stepped(a)
    fleet = _mk(Fleet, n=2, cfg_kw=dict(journal=True), fleet=FleetConfig())
    fleet.run_stepped(b)
    assert _snapshot(a) == _snapshot(b)
    assert all(len(e.journal) > 0 for e in fleet.engines)
    assert fleet.verify_journals() == []
    fs = summarize_fleet(fleet)
    assert fs["journal_checks"] >= 2 and fs["journal_mismatches"] == []
    assert all(r["journal_records"] > 0 for r in fs["replicas"])


# ---------------- restart & rejoin -------------------------------------------


def test_kill_restart_rejoin_cycle():
    plan = FaultPlan(replica_kills={1: 4.0}, restart_delays={1: 2.0})
    fleet = _mk(Fleet, n=2, plan=plan, cfg_kw=dict(journal=True),
                fleet=FleetConfig(restart_warmup_s=1.0))
    reqs = _wl(60, seed=12, rate=5.0)
    done = fleet.run_stepped(reqs)
    assert len(fleet.kill_events) == 1       # schedule never re-fires on
    #                                          the fresh engine
    assert len(fleet.restart_events) == 1
    ev = fleet.restart_events[0]
    assert ev["replica"] == 1
    assert ev["restarted"] >= ev["died"] + 2.0
    assert ev["rejoin_at"] >= ev["restarted"] + 1.0
    assert any(h["state"] == "rejoined" and h["replica"] == 1
               for h in fleet.health_events)
    assert fleet.replica_state[1] is ReplicaState.HEALTHY
    assert len(fleet.retired) == 1
    # the fresh engine re-entered routing and did real work
    assert fleet.engines[1].finished
    counts = lifecycle_counts(reqs)
    assert counts["finished"] == len(reqs)
    # Fleet.run_stepped counts retired-engine completions exactly once
    assert sorted(r.rid for r in done) == sorted(r.rid for r in reqs)
    _assert_recovery_clean(fleet, reqs)


def test_restart_warms_prefix_trie_from_healthiest_peer():
    plan = FaultPlan(replica_kills={1: 5.0}, restart_delays={1: 1.0})
    fleet = _mk(Fleet, n=3, plan=plan, cfg_kw=dict(journal=True),
                fleet=FleetConfig(restart_warm_pages=256,
                                  restart_warmup_s=1.0))
    reqs = _wl(80, seed=13, rate=6.0)
    fleet.run_stepped(reqs)
    ev = fleet.restart_events[0]
    assert ev["warm_source"] is not None and ev["warm_source"] != 1
    assert ev["warm_pages_imported"] + ev["warm_pages_deduped"] > 0
    # the rejoin gate waited for both warm-up dwell and the transfer
    assert ev["rejoin_at"] >= ev["restarted"] + 1.0
    # warmed pages enter as cached/evictable content, never ownership
    _assert_recovery_clean(fleet, reqs)


def test_drained_replica_restarts_on_fleet_schedule():
    fleet = _mk(Fleet, n=3, cfg_kw=dict(journal=True),
                fleet=FleetConfig(drains={1: 5.0}, restarts={1: 3.0},
                                  restart_warm_pages=128,
                                  restart_warmup_s=1.0))
    reqs = _wl(120, seed=3, rate=4.0)
    fleet.run_stepped(reqs)
    assert len(fleet.drain_events) == 1      # the drain entry fires once:
    #                                          no re-drain after rejoin
    assert fleet.drain_events[0]["cause"] == "operator"
    ev = fleet.restart_events[0]
    assert ev["replica"] == 1 and ev["died"] is not None
    assert fleet.engines[1].finished         # fresh work post-rejoin
    _assert_recovery_clean(fleet, reqs)


def test_whole_fleet_outage_with_armed_restart_loses_nothing():
    """Both replicas die at once; one has a scheduled restart. The
    outage is transient: the crashed in-flight is orphaned (not lost),
    the restart fires by jumping the dead clock, and the rejoined slot
    finishes the entire workload."""
    plan = FaultPlan(replica_kills={0: 1.0, 1: 1.0},
                     restart_delays={0: 2.0})
    fleet = _mk(Fleet, n=2, plan=plan, cfg_kw=dict(journal=True),
                fleet=FleetConfig())
    reqs = _wl(50, seed=16, rate=4.0)
    done = fleet.run_stepped(reqs)
    counts = lifecycle_counts(reqs)
    assert counts["finished"] == len(reqs)
    assert len(done) == len(reqs)
    assert len(fleet.restart_events) == 1
    _assert_recovery_clean(fleet, reqs)


def test_kill_recovery_manifest_comes_from_journal():
    """A busy-replica crash recovers its in-flight from the journal's
    replayed stage map; the recovered set matches the live derivation
    (zero mismatches) and the redispatch count."""
    plan = FaultPlan(replica_kills={0: 2.0}, restart_delays={0: 5.0})
    fleet = _mk(Fleet, n=3, plan=plan, cfg_kw=dict(journal=True),
                fleet=FleetConfig())
    reqs = _wl(80, seed=5, rate=8.0)
    fleet.run_stepped(reqs)
    ev = fleet.kill_events[0]
    assert "recovered_stages" in ev
    assert sum(ev["recovered_stages"].values()) == ev["redispatched"]
    _assert_recovery_clean(fleet, reqs)


# ---------------- health-scored auto-drain -----------------------------------


def test_auto_drain_after_persistent_degradation():
    """Tiny backlog threshold keeps replicas DEGRADED; after
    ``auto_drain_window`` consecutive ticks each starts its own graceful
    drain through the operator path, tagged cause="auto"."""
    fleet = _mk(Fleet, n=3, cfg_kw=dict(journal=True),
                fleet=FleetConfig(degraded_backlog=2, health_window=2,
                                  auto_drain_window=4))
    reqs = _wl(100, seed=15, rate=10.0)
    fleet.run_stepped(reqs)
    autos = [ev for ev in fleet.drain_events if ev["cause"] == "auto"]
    assert autos
    assert any(h.get("cause") == "auto" and h["state"] == "draining"
               for h in fleet.health_events)
    _assert_recovery_clean(fleet, reqs)


# ---------------- satellite: _route fallback load accounting -----------------


def test_route_fallback_does_not_leak_load_onto_ineligible_replica():
    """Regression (ISSUE 10 satellite): the inherited least-loaded mode
    bumps ``_load[i]`` before the fleet discovers i is ineligible; the
    fallback must remove that bump or dead/draining replicas accumulate
    phantom load that skews every comparison after they restart."""
    fleet = _mk(Fleet, n=2, fleet=FleetConfig())
    fleet.replica_state[0] = ReplicaState.DRAINING
    for r in _wl(10, seed=9):
        assert fleet._route(r) == 1
    assert fleet._load[0] == 0.0
    assert fleet._load[1] > 0.0


# ---------------- the recovery chaos property --------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000),
       kill_t=st.floats(0.0, 12.0),     # < 1.0 means "no kill"
       drain_t=st.floats(1.0, 12.0),
       restart_delay=st.floats(0.5, 5.0),
       warm=st.sampled_from([0, 128]),
       n_replicas=st.sampled_from([2, 3]))
def test_any_restart_schedule_conserves_and_replays_exactly(
        seed, kill_t, drain_t, restart_delay, warm, n_replicas):
    """Whatever the sampled schedule does — a kill racing a drain, every
    replica armed to restart, warm imports on or off — pages and pins
    are conserved fleet-wide (retired engines included), each request
    lands in exactly one terminal state on exactly one replica, and
    every journal replays to its live accounting bit-exactly."""
    kills = {n_replicas - 1: kill_t} if kill_t >= 1.0 else {}
    plan = FaultPlan(seed=seed, replica_kills=kills,
                     restart_delays={i: restart_delay
                                     for i in range(n_replicas)})
    fleet = _mk(Fleet, n=n_replicas, plan=plan, cfg_kw=dict(journal=True),
                fleet=FleetConfig(drains={0: drain_t},
                                  restart_warm_pages=warm,
                                  restart_warmup_s=1.0))
    reqs = _wl(40, seed=seed % 100)
    done = fleet.run_stepped(reqs)
    _assert_recovery_clean(fleet, reqs)
    assert sorted(r.rid for r in done) == \
        sorted(r.rid for r in reqs if r.state is State.FINISHED)
