"""Decoupled vision-encode pipeline tests (ISSUE 2 tentpole).

Properties enforced:
  * time accounting conserves work — chunked encode sums to exactly the
    unchunked encode cost, and iteration durations decompose into
    llm + encode - overlap_saved;
  * the encoder cache never changes outputs (identical finished sets and
    decoded token counts) and only improves mean TTFT;
  * fast-path scheduling decisions stay bit-identical to
    ``legacy_scheduling`` on multimodal mixes exercising the encode queue,
    chunking, and the cache.
"""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.scheduler import make_policy
from repro.serving.encoder_cache import EncoderCache
from repro.serving.engine import Engine, EngineConfig
from repro.serving.executors import SimExecutor
from repro.serving.request import Modality, Request, State
from repro.serving.workload import WorkloadConfig, generate

from conftest import sim_stack_cached as _sim_stack


def _engine(policy="tcm", *, overlap=True, cache=True, legacy=False,
            encode_budget=2048, token_budget=512, kv_pages=24576):
    executor, classifier, _, _, _ = _sim_stack()
    ex = SimExecutor(executor.cm, overlap=overlap)
    eng = Engine(make_policy(policy), ex, classifier,
                 EngineConfig(token_budget=token_budget, kv_pages=kv_pages,
                              encode_budget=encode_budget,
                              encoder_cache=cache,
                              legacy_scheduling=legacy))
    return eng, ex


def _fingerprint(done):
    return [(r.rid, r.first_token_time, r.finish_time, r.preemptions,
             r.encode_finish_time, r.encode_cache_hit) for r in done]


# ---------------- pipeline stages -------------------------------------------


def test_mm_request_flows_through_encoding_state():
    eng, _ = _engine(encode_budget=500, cache=False)
    video = Request(rid="v0", modality=Modality.VIDEO, arrival=0.0,
                    text_tokens=16, mm_units=1960, output_tokens=4,
                    prompt_tokens=1976)
    pending = [video]
    saw_encoding = False
    for _ in range(100):
        pending = eng.step(pending)
        saw_encoding |= video.state is State.ENCODING
        if video.state is State.FINISHED:
            break
    assert video.state is State.FINISHED
    assert saw_encoding, "mm request never entered the ENCODING stage"
    # budgeted chunking: 1960 units at 500/iter -> 4 encode iterations
    assert video.encoded_units == 1960
    assert video.encode_start_time is not None
    assert video.encode_finish_time >= video.encode_start_time
    assert video.encode_finish_time <= video.admit_time
    bd = video.ttft_breakdown()
    assert bd["encode"] > 0
    assert video.ttft() == pytest.approx(sum(bd.values()))


def test_nonpositive_encode_budget_rejected():
    """A zero/negative budget would strand ENCODING requests forever."""
    executor, classifier, _, _, _ = _sim_stack()
    with pytest.raises(ValueError):
        Engine(make_policy("tcm"), executor, classifier,
               EngineConfig(encode_budget=0))


def test_text_requests_skip_encode_queue():
    eng, _ = _engine()
    txt = Request(rid="t0", modality=Modality.TEXT, arrival=0.0,
                  text_tokens=64, prompt_tokens=64, output_tokens=4)
    done = eng.run([txt])
    assert done and txt.encode_start_time is None
    assert len(eng.encode_queues) == 0
    bd = txt.ttft_breakdown()
    assert bd["encode"] == bd["encode_wait"] == 0.0


# ---------------- work conservation -----------------------------------------


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000), budget=st.sampled_from([256, 2048, 8192]))
def test_encode_accounting_conserves_work(seed, budget):
    """Chunked encode must sum to exactly the unchunked per-request encode
    cost (no work lost or invented at chunk boundaries), and the engine
    clock must decompose into the executor's stage counters."""
    eng, ex = _engine(cache=False, encode_budget=budget)
    done = eng.run(generate(WorkloadConfig(mix="MH", rate=3.0,
                                           num_requests=60, seed=seed)))
    expected = sum(ex.cm.encode_time(r) for r in done if r.mm_units > 0)
    assert ex.encode_seconds == pytest.approx(expected, rel=1e-9)
    assert ex.busy_seconds >= \
        ex.llm_seconds + ex.encode_seconds - ex.overlap_saved_seconds - 1e-9
    assert ex.overlap_saved_seconds <= \
        ex.cm.overlap_efficiency * min(ex.llm_seconds, ex.encode_seconds)


def test_no_overlap_serializes_stages():
    eng, ex = _engine(overlap=False, cache=False)
    eng.run(generate(WorkloadConfig(mix="MH", rate=3.0, num_requests=40,
                                    seed=5)))
    assert ex.overlap_saved_seconds == 0.0
    assert ex.encode_seconds > 0


def test_overlap_improves_mean_ttft():
    wl = WorkloadConfig(mix="MH", rate=2.5, num_requests=120, seed=7,
                        video_frames_max=96)
    ttfts = {}
    for overlap in (True, False):
        eng, _ = _engine(overlap=overlap, cache=False)
        done = eng.run(generate(wl))
        ttfts[overlap] = sum(r.ttft() for r in done) / len(done)
    assert ttfts[True] < ttfts[False]


# ---------------- encoder cache ---------------------------------------------


def test_encoder_cache_lru_and_stats():
    c = EncoderCache(capacity=2)
    assert not c.lookup("a")
    c.insert("a", 10)
    c.insert("b", 20)
    assert c.lookup("a")          # refreshes a's recency
    c.insert("c", 30)             # evicts b (LRU)
    assert "b" not in c and "a" in c and "c" in c
    s = c.stats()
    assert s["hits"] == 1 and s["misses"] == 1 and s["evictions"] == 1
    with pytest.raises(ValueError):
        EncoderCache(capacity=0)


@settings(max_examples=4, deadline=None)
@given(seed=st.sampled_from(list(range(8))))  # bounded: every seed verified
def test_cache_hits_never_change_outputs_ttft_only_improves(seed):
    wl = WorkloadConfig(mix="MH", rate=2.5, num_requests=80, seed=seed,
                        duplicate_prob=0.5)
    runs = {}
    for cache in (True, False):
        eng, _ = _engine(cache=cache)
        done = eng.run(generate(wl))
        runs[cache] = (eng, done)
    eng_on, done_on = runs[True]
    _, done_off = runs[False]
    # outputs unchanged: same finished set, same decoded work per request
    assert {r.rid for r in done_on} == {r.rid for r in done_off}
    assert {r.rid: r.decoded for r in done_on} == \
        {r.rid: r.decoded for r in done_off}
    # TTFT only improves in aggregate, and strictly for the hit requests
    mean_on = sum(r.ttft() for r in done_on) / len(done_on)
    mean_off = sum(r.ttft() for r in done_off) / len(done_off)
    assert mean_on <= mean_off * (1 + 1e-9)
    hits = [r for r in done_on if r.encode_cache_hit]
    if hits:
        off_by_rid = {r.rid: r for r in done_off}
        hit_on = sum(r.ttft() for r in hits) / len(hits)
        hit_off = sum(off_by_rid[r.rid].ttft() for r in hits) / len(hits)
        assert hit_on <= hit_off * (1 + 1e-9)
        assert eng_on.encoder_cache.hits >= len(hits)
        for r in hits:
            assert r.encode_start_time is None  # encode skipped entirely


def test_unhashed_mm_requests_bypass_cache():
    eng, _ = _engine()
    r = Request(rid="img", modality=Modality.IMAGE, arrival=0.0,
                text_tokens=16, mm_units=576, prompt_tokens=592,
                output_tokens=4)  # mm_hash=None
    done = eng.run([r])
    assert done and not r.encode_cache_hit
    assert eng.encoder_cache.hits == eng.encoder_cache.misses == 0
    assert len(eng.encoder_cache) == 0


# ---------------- fast vs legacy parity on multimodal mixes ------------------


@pytest.mark.parametrize("policy", ["fcfs", "edf", "static", "naive-aging",
                                    "tcm"])
def test_encode_pipeline_parity_with_legacy(policy):
    """Chunked encode + cache must not change *scheduling decisions*: the
    incremental encode queue (WaitingIndex reuse) matches the legacy
    brute-force ordering bit for bit, duplicates and tiny budgets
    included."""
    wl = WorkloadConfig(mix="MH", rate=3.0, num_requests=80, seed=11,
                        duplicate_prob=0.4)
    fps = {}
    for legacy in (False, True):
        eng, _ = _engine(policy, legacy=legacy, encode_budget=640,
                         kv_pages=2048)
        done = eng.run(generate(wl))
        fps[legacy] = (_fingerprint(done), eng.iterations, eng.now)
    assert fps[False] == fps[True], \
        f"{policy}: encode pipeline diverged between fast and legacy paths"


def test_encode_index_drains_clean():
    eng, _ = _engine(encode_budget=512)
    done = eng.run(generate(WorkloadConfig(mix="MH", rate=4.0,
                                           num_requests=50, seed=13)))
    assert len(done) + len(eng.rejected) == 50
    assert len(eng.encode_queues) == 0
    assert len(eng.encode_index) == 0
    assert len(eng.wait_index) == 0
