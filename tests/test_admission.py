"""Overload control (ISSUE 8): SLO-aware admission, the brownout
ladder, trace-shaped workloads, and completed-only latency metrics.

The central properties: admission decisions are deterministic and
modality-aware (rocks refused first, sand last); the ladder cannot
oscillate at a fixed boundary load; an installed admission layer is a
bit-exact no-op under capacity; and ANY overload schedule composed with
ANY fault schedule leaves zero leaks, non-negative token buckets, and
every request in exactly one terminal state."""
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import sim_stack_cached
from repro.core.scheduler import make_policy
from repro.serving.admission import (AdmissionConfig, AdmissionController,
                                     BrownoutConfig, BrownoutLadder,
                                     TenantBudget, TokenBucket,
                                     legacy_shed_config)
from repro.serving.engine import Engine, EngineConfig
from repro.serving.executors import SimExecutor, make_cost_model
from repro.serving.faults import FaultPlan, FaultRates
from repro.serving.metrics import (lifecycle_counts, rejection_mix,
                                   summarize, summarize_tenants)
from repro.serving.request import Modality, Request, State, VehicleClass
from repro.serving.workload import WorkloadConfig, generate

POLICY = "tcm"


def _engine(plan=None, **cfg_kw):
    _ex, classifier, _cfg, _prof, _est = sim_stack_cached()
    cfg_kw.setdefault("kv_pages", 2048)
    cfg_kw.setdefault("token_budget", 512)
    return Engine(make_policy(POLICY), SimExecutor(make_cost_model(
        "llava-7b")), classifier, EngineConfig(**cfg_kw), faults=plan)


def _wl(n=40, seed=0, **kw):
    kw.setdefault("rate", 3.0)
    return generate(WorkloadConfig(mix="MH", num_requests=n,
                                   seed=seed, **kw))


def _classified(eng, rid, modality, text, mm, slo=None):
    req = Request(rid=rid, modality=modality, arrival=eng.now,
                  text_tokens=text, mm_units=mm, prompt_tokens=text + mm)
    vclass, est_prefill, est_kv = eng.classifier.classify(
        modality.value, text, mm)
    req.vclass = vclass
    req.est_prefill = est_prefill
    req.est_kv_tokens = est_kv
    req.slo = (slo if slo is not None
               else eng.config.slo_scale * eng.executor.isolated_e2e(req))
    return req


def _assert_clean(eng, reqs):
    eng.allocator.check_invariants()
    assert eng.allocator.used_pages == 0
    if eng.encoder_cache is not None:
        stats = eng.encoder_cache.stats()
        assert stats["pin_refs"] == 0 and stats["pinned"] == 0
    assert eng._enc_pins == {}
    counts = lifecycle_counts(reqs)
    assert counts["in_flight"] == 0
    assert (counts["finished"] + counts["rejected"] + counts["failed"]
            + counts["cancelled"]) == len(reqs)
    done = {r.rid for r in eng.finished}
    assert len(done) == len(eng.finished)
    assert done.isdisjoint(r.rid for r in eng.aborted)
    assert done.isdisjoint(r.rid for r in eng.rejected)


# ---------------- token buckets ---------------------------------------------


def test_token_bucket_never_negative():
    b = TokenBucket(TenantBudget(rate=10.0, burst=100.0), now=0.0)
    assert b.take(60.0, 0.0)
    assert not b.take(50.0, 0.0)     # 40 left: refused whole, not debited
    assert b.level == 40.0
    assert b.take(40.0, 0.0)
    assert b.level == 0.0
    assert b.min_level == 0.0
    # refill at 10 tok/s; clock moves forward only
    assert not b.take(25.0, 2.0)     # 20 refilled: still short
    assert b.take(25.0, 3.0)         # 30 >= 25 after one more second
    assert b.min_level >= 0.0


def test_token_bucket_caps_at_burst_and_infinite_is_free():
    b = TokenBucket(TenantBudget(rate=1000.0, burst=50.0), now=0.0)
    b.refill(1e9)
    assert b.level == 50.0           # capped at burst
    inf = TokenBucket(TenantBudget(), now=0.0)
    assert inf.take(1e18, 0.0) and inf.min_level == float("inf")


def test_controller_lazy_buckets_and_min_level():
    ctl = AdmissionController(AdmissionConfig(
        tenant_budgets={"a": TenantBudget(rate=1.0, burst=10.0)}))
    assert ctl.min_bucket_level() == float("inf")   # no bucket yet
    assert ctl.bucket_for("a", 0.0).take(9.0, 0.0)
    assert ctl.min_bucket_level() == 1.0
    assert ctl.bucket_for("b", 0.0).take(1e9, 0.0)  # default: infinite


# ---------------- admission feasibility -------------------------------------


def test_predict_ttft_backlog_is_class_aware():
    """A motorcycle only waits behind other motorcycles; a truck waits
    behind everything — queued rocks must not count against sand."""
    eng = _engine(None, admission=AdmissionConfig())
    moto = _classified(eng, "m", Modality.TEXT, 64, 0)
    truck = _classified(eng, "t", Modality.VIDEO, 64, 12000)
    assert moto.vclass is VehicleClass.MOTORCYCLE
    assert truck.vclass is VehicleClass.TRUCK
    base_m = eng.admission.predict_ttft(moto, eng)
    base_t = eng.admission.predict_ttft(truck, eng)
    # park a big rock in the waiting queue: only the truck's prediction
    # may move
    parked = _classified(eng, "parked", Modality.VIDEO, 64, 12000)
    eng.queues.push(parked, eng.now)
    assert eng.admission.predict_ttft(moto, eng) == base_m
    assert eng.admission.predict_ttft(truck, eng) > base_t
    # a parked motorcycle delays both (it runs ahead of everything)
    parked_m = _classified(eng, "pm", Modality.TEXT, 64, 0)
    eng.queues.push(parked_m, eng.now)
    assert eng.admission.predict_ttft(moto, eng) > base_m


def test_feasibility_rejects_backlogged_truck_admits_moto():
    eng = _engine(None, admission=AdmissionConfig())
    # queue enough rock-seconds that a new truck cannot meet its SLO
    for i in range(12):
        eng.queues.push(
            _classified(eng, f"bk{i}", Modality.VIDEO, 64, 12000), eng.now)
    truck = _classified(eng, "t", Modality.VIDEO, 64, 12000)
    moto = _classified(eng, "m", Modality.TEXT, 64, 0)
    reason = eng.admission.decide(truck, eng)
    assert reason is not None and "SLO infeasible" in reason
    assert eng.admission.decide(moto, eng) is None
    assert eng.admission.rejections and eng.admission.admitted == 1


def test_queue_depth_bound_and_decision_order():
    """A zero-depth truck queue rejects structurally — before the
    feasibility model runs and before the tenant bucket is debited."""
    cfg = AdmissionConfig(
        max_queue_depth={VehicleClass.TRUCK: 0},
        tenant_budgets={"default": TenantBudget(rate=0.0, burst=100.0)})
    eng = _engine(None, admission=cfg)
    truck = _classified(eng, "t", Modality.VIDEO, 64, 12000)
    reason = eng.admission.decide(truck, eng)
    assert reason is not None and "queue full" in reason
    assert not eng.admission.buckets     # bucket never touched
    # the bucket is consulted last: an admissible moto drains it...
    moto = _classified(eng, "m", Modality.TEXT, 64, 0)
    assert eng.admission.decide(moto, eng) is None
    # ...and once empty, the next moto is refused on budget
    moto2 = _classified(eng, "m2", Modality.TEXT, 64, 0)
    reason = eng.admission.decide(moto2, eng)
    assert reason is not None and "budget exhausted" in reason
    assert eng.admission.min_bucket_level() >= 0.0


def test_rejected_is_terminal_and_distinct_in_metrics():
    """REJECTED rides the exactly-once release path and is counted apart
    from FAILED/CANCELLED."""
    eng = _engine(None, admission=AdmissionConfig(
        max_queue_depth={VehicleClass.TRUCK: 0}))
    reqs = _wl(30, seed=2, rate=50.0)
    eng.run(reqs)
    rej = [r for r in reqs if r.state is State.REJECTED]
    assert rej and all(r in eng.rejected for r in rej)
    assert all(r.aborted_at is not None and r.finish_time is None
               for r in rej)
    counts = lifecycle_counts(reqs)
    assert counts["rejected"] == len(rej)
    assert counts["failed"] == counts["cancelled"] == 0
    _assert_clean(eng, reqs)


def test_overload_rejection_is_modality_ordered():
    """Sustained overload refuses rocks at the highest rate and sand at
    the lowest (the benchmark gates the same order at scale)."""
    eng = _engine(None, admission=AdmissionConfig())
    reqs = _wl(120, seed=3, rate=30.0)
    eng.run(reqs)
    mix = rejection_mix(reqs)
    assert mix["truck"]["rejected"] > 0
    assert mix["truck"]["rate"] >= mix["car"]["rate"] \
        >= mix["motorcycle"]["rate"]
    _assert_clean(eng, reqs)


def test_admission_installed_is_noop_under_capacity():
    """Permissive defaults: under capacity the layer admits everything
    and the run is bit-identical to no layer at all."""
    def run(admission):
        eng = _engine(None, kv_pages=4096, admission=admission)
        reqs = _wl(60, seed=4, rate=1.0)
        eng.run(reqs)
        assert all(r.state is not State.REJECTED for r in reqs)
        return {r.rid: (r.state.value, r.finish_time, r.first_token_time,
                        r.decoded, r.preemptions) for r in reqs}
    assert run(AdmissionConfig()) == run(None)


# ---------------- brownout ladder -------------------------------------------


def test_ladder_climbs_rungs_in_order_then_sheds():
    lad = BrownoutLadder(BrownoutConfig(step_iters=3, cooldown_iters=5))
    names = ["encode", "defer_trucks", "publication"]
    for lvl, name in enumerate(names):
        assert not lad.active(name)
        for _ in range(3):
            assert lad.observe(True) is False
        assert lad.level == lvl + 1 and lad.active(name)
    # at the top: the next step_iters of pressure request a shed
    assert [lad.observe(True) for _ in range(3)] == [False, False, True]
    lad.shed_fired()                     # half-reset: sheds every 2 now
    assert [lad.observe(True) for _ in range(2)] == [False, True]


def test_ladder_descends_only_after_cooldown():
    lad = BrownoutLadder(BrownoutConfig(step_iters=2, cooldown_iters=4))
    for _ in range(4):
        lad.observe(True)
    assert lad.level == 2
    for _ in range(3):
        lad.observe(False)
    assert lad.level == 2                # cooldown not yet met
    lad.observe(False)
    assert lad.level == 1                # one rung per full cooldown
    for _ in range(4):
        lad.observe(False)
    assert lad.level == 0


def test_ladder_no_oscillation_at_boundary_load():
    """Alternating pressure/clean at a fixed boundary load must not
    oscillate: climbing needs a pressure *streak*, descending a clean
    streak, and strict alternation provides neither."""
    lad = BrownoutLadder(BrownoutConfig(step_iters=4, cooldown_iters=8))
    for _ in range(4):
        lad.observe(True)
    assert lad.level == 1 and lad.transitions == 1
    for i in range(200):
        assert lad.observe(bool(i % 2)) is False
    assert lad.level == 1 and lad.transitions == 1


def test_legacy_shed_config_matches_pr6_cadence():
    """load_shed's absorbed mapping: shed at N sustained-pressure
    iterations, half-reset after a confirmed shed, full reset on any
    clean iteration, and no graded rungs ever engage."""
    lad = BrownoutLadder(legacy_shed_config(6))
    assert [lad.observe(True) for _ in range(6)] == [False] * 5 + [True]
    assert lad.observe(True) is True     # unconfirmed: retries at once
    lad.shed_fired()
    assert [lad.observe(True) for _ in range(3)] == [False, False, True]
    lad.observe(False)                   # clean: full reset
    assert [lad.observe(True) for _ in range(6)] == [False] * 5 + [True]
    assert lad.level == 0 and not any(
        lad.active(r) for r in ("encode", "defer_trucks", "publication"))


def test_engine_brownout_engages_before_shedding():
    """Under page pressure with a graded ladder, rung degradations fire
    (transitions observed) and service continues — sheds only at the
    top."""
    eng = _engine(None, kv_pages=700, max_num_seqs=128,
                  admission=AdmissionConfig(slo_feasibility=False,
                                            max_queue_depth=None),
                  brownout=BrownoutConfig(step_iters=3, cooldown_iters=6))
    reqs = _wl(60, seed=8, rate=50.0)
    eng.run(reqs)
    assert eng.ladder.transitions > 0
    if eng.shed_count:                   # sheds stay modality-aware
        shed = [r for r in reqs if r.error is not None
                and r.error.startswith("load shed")]
        assert all(r.vclass is not VehicleClass.MOTORCYCLE for r in shed)
    _assert_clean(eng, reqs)


# ---------------- metrics: completed-only percentiles (satellite) ------------


def _mk(rid, state, vclass, ttft=None, finish=None, tenant="default",
        out=8):
    r = Request(rid=rid, modality=Modality.TEXT, arrival=0.0,
                text_tokens=10, prompt_tokens=10, output_tokens=out,
                tenant=tenant)
    r.vclass = vclass
    r.state = state
    r.slo = 100.0
    r.first_token_time = ttft
    r.finish_time = finish
    if state in (State.REJECTED, State.FAILED, State.CANCELLED):
        r.aborted_at = 1.0
        r.error = ("admission: x" if state is State.REJECTED
                   else "load shed: x" if state is State.FAILED
                   else "client cancel")
    return r


def test_summarize_excludes_non_completed_from_latency():
    """Regression (ISSUE 8 satellite): a FAILED request with a recorded
    first token must not drag TTFT percentiles; REJECTED/shed/FAILED are
    reported as separate counts."""
    M = VehicleClass.MOTORCYCLE
    reqs = [_mk("f1", State.FINISHED, M, ttft=1.0, finish=2.0),
            _mk("f2", State.FINISHED, M, ttft=3.0, finish=4.0),
            # failed mid-decode with a huge recorded first-token time:
            # the seed folded this 100s into the percentiles
            _mk("x1", State.FAILED, M, ttft=100.0),
            _mk("r1", State.REJECTED, M),
            _mk("c1", State.CANCELLED, M)]
    s = summarize(reqs)["overall"]
    assert s["n"] == 5 and s["finished"] == 2
    assert s["rejected"] == 1 and s["failed"] == 1 and s["cancelled"] == 1
    assert s["shed"] == 1
    assert s["ttft_avg"] == 2.0          # (1+3)/2, not (1+3+100)/3
    assert s["ttft_p90"] < 3.1 and s["ttft_p99"] < 3.1
    assert s["slo_violation_rate"] == 0.0


def test_summarize_tenants_counters_and_fairness_signal():
    M, T = VehicleClass.MOTORCYCLE, VehicleClass.TRUCK
    reqs = ([_mk(f"a{i}", State.FINISHED, M, ttft=0.5, finish=1.0,
                 tenant="a") for i in range(4)]
            + [_mk("a-t", State.REJECTED, T, tenant="a")]
            + [_mk(f"b{i}", State.FINISHED, T, ttft=2.0, finish=3.0,
                   tenant="b") for i in range(2)]
            + [_mk("b-r", State.REJECTED, M, tenant="b")])
    t = summarize_tenants(reqs, duration=10.0)
    assert t["a"]["finished"] == 4 and t["a"]["rejected"] == 1
    assert t["a"]["served_by_class"]["motorcycle"] == 4
    assert t["a"]["rejected_by_class"]["truck"] == 1
    assert t["b"]["served_by_class"]["truck"] == 2
    assert t["a"]["goodput"] == 0.4      # 4 in-SLO / 10 s
    assert 0 < t["b"]["slo_attainment"] < 1


# ---------------- trace-shaped workloads (tentpole part 3) ------------------


def test_trace_workload_deterministic_and_tenanted():
    cfg = WorkloadConfig(mix="MH", rate=4.0, num_requests=120, seed=11,
                         tenants=3, heavy_tail_prob=0.1,
                         diurnal_amplitude=0.5, burst_prob=0.05)
    a, b = generate(cfg), generate(cfg)
    assert [(r.rid, r.tenant, r.arrival, r.text_tokens, r.output_tokens,
             r.shared_prefix_id) for r in a] == \
           [(r.rid, r.tenant, r.arrival, r.text_tokens, r.output_tokens,
             r.shared_prefix_id) for r in b]
    tenants = {r.tenant for r in a}
    assert tenants == {"tenant0", "tenant1", "tenant2"}
    # tenant system prompts feed the prefix cache with shared content
    sys_ids = {r.shared_prefix_id for r in a if r.shared_prefix_id}
    assert sys_ids <= {"t11-0", "t11-1", "t11-2"} and sys_ids
    assert all(r.text_tokens <= cfg.heavy_tail_text_cap for r in a)
    assert all(r.output_tokens <= cfg.heavy_tail_out_cap for r in a)


def test_trace_knobs_off_draw_nothing_extra():
    base = WorkloadConfig(mix="MH", rate=2.0, num_requests=80, seed=5)
    plain = generate(base)
    assert all(r.tenant == "default" for r in plain)
    # enabling trace knobs must not perturb the base stream's draws:
    # arrivals shift (shaping) but sizes of untouched requests match
    shaped = generate(WorkloadConfig(mix="MH", rate=2.0, num_requests=80,
                                     seed=5, diurnal_amplitude=0.3))
    assert [(r.text_tokens, r.mm_units, r.output_tokens)
            for r in shaped] == \
           [(r.text_tokens, r.mm_units, r.output_tokens) for r in plain]
    assert [r.arrival for r in shaped] != [r.arrival for r in plain]


# ---------------- the overload x chaos property -----------------------------


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000),
       rate=st.floats(4.0, 30.0),
       cancel=st.floats(0.0, 0.4), deadline=st.floats(0.0, 0.2),
       encoder=st.floats(0.0, 0.4), step=st.floats(0.0, 0.03),
       kv_pages=st.sampled_from([512, 1024, 2048]),
       budget=st.floats(500.0, 5000.0),
       graded=st.booleans())
def test_any_overload_schedule_with_faults_conserves_resources(
        seed, rate, cancel, deadline, encoder, step, kv_pages, budget,
        graded):
    """Arbitrary overload (rate, tenant budgets, graded brownout or
    legacy shed) composed with an arbitrary FaultPlan: zero leaked
    pages/pins, token buckets never negative, and the workload
    partitions into terminal states (REJECTED included) exactly."""
    plan = FaultPlan(seed=seed, rates=FaultRates(
        cancel_prob=cancel, deadline_prob=deadline,
        encoder_fault_prob=encoder, step_fault_prob=step,
        deadline_min_s=0.5, deadline_max_s=20.0))
    adm = AdmissionConfig(tenant_budgets={
        "tenant0": TenantBudget(rate=budget, burst=budget * 8)})
    brown = (BrownoutConfig(step_iters=5, cooldown_iters=10) if graded
             else legacy_shed_config(10))
    eng = _engine(plan, kv_pages=kv_pages, admission=adm, brownout=brown)
    reqs = generate(WorkloadConfig(
        mix="MH", rate=rate, num_requests=40, seed=seed % 100,
        tenants=3, heavy_tail_prob=0.1, burst_prob=0.05,
        duplicate_prob=0.3))
    eng.run(reqs)
    _assert_clean(eng, reqs)
    assert eng.admission.min_bucket_level() >= 0.0
    assert (eng.admission.admitted
            + sum(eng.admission.rejections.values())
            >= len([r for r in reqs if r.state is State.REJECTED]))
