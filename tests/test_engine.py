"""Engine integration + property tests: continuous batching, chunked
prefill, preemption, allocator safety, end-to-end behaviour."""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cache.allocator import BlockAllocator, OutOfPages
from repro.core.scheduler import make_policy
from repro.launch.serve import serve
from repro.serving.engine import Engine, EngineConfig
from repro.serving.metrics import summarize
from repro.serving.request import State, VehicleClass
from repro.serving.workload import WorkloadConfig, generate


# ---------------- allocator property tests ----------------------------------

@settings(max_examples=30, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 9), st.integers(1, 400),
                              st.booleans()), max_size=60))
def test_allocator_invariants_hold(ops):
    """Random allocate/free sequences never double-allocate or leak pages."""
    alloc = BlockAllocator(num_pages=64, page_size=16)
    for rid_i, tokens, do_free in ops:
        rid = f"r{rid_i}"
        if do_free:
            alloc.free(rid)
        else:
            try:
                alloc.allocate(rid, tokens)
            except OutOfPages:
                pass
        alloc.check_invariants()


def test_allocator_accounting():
    alloc = BlockAllocator(num_pages=10, page_size=16)
    alloc.allocate("a", 33)       # 3 pages
    assert alloc.used_pages == 3
    alloc.allocate("a", 40)       # grow to 3 pages total (ceil(40/16)=3)
    assert alloc.used_pages == 3
    alloc.allocate("a", 49)       # grow to 4
    assert alloc.used_pages == 4
    assert not alloc.can_allocate(16 * 7)
    assert alloc.free("a") == 4
    assert alloc.free_pages == 10


# ---------------- engine end-to-end -----------------------------------------
# (the session-cached sim_stack fixture comes from conftest.py)

@pytest.mark.parametrize("policy", ["fcfs", "edf", "static", "naive-aging",
                                    "tcm"])
def test_engine_completes_all_requests(policy, sim_stack):
    executor, classifier, engine_cfg, _, _ = sim_stack
    eng = Engine(make_policy(policy), executor, classifier, engine_cfg)
    reqs = generate(WorkloadConfig(mix="MH", rate=2.0, num_requests=60, seed=3))
    done = eng.run(reqs)
    assert len(done) == 60
    for r in done:
        assert r.state == State.FINISHED
        assert r.first_token_time is not None
        assert r.finish_time >= r.first_token_time
        assert r.ttft() >= 0
        assert r.decoded >= r.output_tokens
    eng.allocator.check_invariants()
    assert eng.allocator.used_pages == 0  # everything freed


def test_engine_time_monotone_and_ttft_after_arrival(sim_stack):
    executor, classifier, engine_cfg, _, _ = sim_stack
    eng = Engine(make_policy("tcm"), executor, classifier, engine_cfg)
    reqs = generate(WorkloadConfig(mix="MH", rate=4.0, num_requests=40, seed=5))
    done = eng.run(reqs)
    for r in done:
        assert r.first_token_time >= r.arrival
        assert r.first_token_time >= r.ready_at  # preprocess precedes prefill


def test_memory_pressure_preempts_rejects_and_completes(sim_stack):
    executor, classifier, _, _, _ = sim_stack
    cfg = EngineConfig(token_budget=512, kv_pages=1024)  # ~16k tokens only
    eng = Engine(make_policy("fcfs"), executor, classifier, cfg)
    reqs = generate(WorkloadConfig(mix="MH", rate=2.0, num_requests=40, seed=9))
    done = eng.run(reqs)
    # over-capacity videos rejected by admission control; the rest complete
    assert len(done) + len(eng.rejected) == 40
    assert all(r.prompt_tokens + r.output_tokens > 1024 * 16 * 0.9
               for r in eng.rejected)
    assert len(done) >= 30
    eng.allocator.check_invariants()


def test_tcm_zero_motorcycle_preemptions_under_pressure(sim_stack):
    executor, classifier, _, _, _ = sim_stack
    cfg = EngineConfig(token_budget=512, kv_pages=1536)
    eng = Engine(make_policy("tcm"), executor, classifier, cfg)
    reqs = generate(WorkloadConfig(mix="MH", rate=2.5, num_requests=60, seed=11))
    done = eng.run(reqs)
    s = summarize(done)
    assert s["motorcycle"]["preemptions"] == 0


def test_tcm_beats_fcfs_on_motorcycle_ttft(sim_stack):
    executor, classifier, engine_cfg, _, _ = sim_stack
    results = {}
    for pol in ["fcfs", "tcm"]:
        eng = Engine(make_policy(pol), executor, classifier, engine_cfg)
        reqs = generate(WorkloadConfig(mix="MH", rate=2.0, num_requests=80,
                                       seed=13, video_frames_max=96))
        results[pol] = summarize(eng.run(reqs))
    assert results["tcm"]["motorcycle"]["ttft_avg"] < \
        0.6 * results["fcfs"]["motorcycle"]["ttft_avg"]


def test_requests_conserved_through_engine(sim_stack):
    """No request is lost or duplicated across queue/prefill/run/finish."""
    executor, classifier, _, _, _ = sim_stack
    cfg = EngineConfig(token_budget=512, kv_pages=2048)
    eng = Engine(make_policy("tcm"), executor, classifier, cfg)
    reqs = generate(WorkloadConfig(mix="MH", rate=3.0, num_requests=50, seed=21))
    pending = sorted(reqs, key=lambda r: r.arrival)
    seen_finished = set()
    for _ in range(200000):
        pending = eng.step(pending)
        ids = ([r.rid for r in pending] + [r.rid for r in eng.queues.peek_all()]
               + [r.rid for r in eng.encode_queues.peek_all()]
               + [r.rid for r in eng.prefilling] + [r.rid for r in eng.running]
               + [r.rid for r in eng.finished])
        assert len(ids) == len(set(ids)) == 50
        seen_finished = {r.rid for r in eng.finished}
        if len(seen_finished) == 50:
            break
    assert len(seen_finished) == 50


# ---------------- real-JAX executor end-to-end ------------------------------

def test_engine_with_real_model_executor():
    """Engine over the actual reduced JAX model (proves the full stack)."""
    done, eng = serve(
        "chatglm3-6b", "tcm",
        WorkloadConfig(mix="ML", rate=50.0, num_requests=6, seed=1,
                       out_tokens_log_mu=1.5, out_tokens_log_sigma=0.2,
                       text_tokens_log_mu=3.0, text_tokens_log_sigma=0.5,
                       video_frames_min=1, video_frames_max=2,
                       image_patches=32, video_patches_per_frame=16),
        executor_kind="real")
    assert len(done) == 6
    for r in done:
        assert r.state == State.FINISHED
        assert r.ttft() is not None


# ---------------- multi-replica router ---------------------------------------

def _router(sim_stack, routing, n_replicas=3):
    from repro.serving.executors import SimExecutor
    from repro.serving.router import Router
    executor, classifier, _, _, _ = sim_stack
    return Router(executors=[SimExecutor(executor.cm)
                             for _ in range(n_replicas)],
                  classifier=classifier, engine_cfg=EngineConfig(),
                  routing=routing)


def _mk(rid, modality=None, text=64, mm=0, arrival=0.0):
    from repro.serving.request import Modality, Request
    return Request(rid=rid, modality=modality or Modality.TEXT,
                   arrival=arrival, text_tokens=text, mm_units=mm,
                   prompt_tokens=text + mm)


def test_router_round_robin_starts_at_replica_zero(sim_stack):
    """Regression: _rr was incremented before returning, so replica 0 was
    skipped on the first assignment and load started skewed."""
    router = _router(sim_stack, "round-robin")
    picks = [router._route(_mk(f"r{i}")) for i in range(7)]
    assert picks == [0, 1, 2, 0, 1, 2, 0]


def test_router_least_loaded_tracks_estimated_prefill(sim_stack):
    from repro.serving.request import Modality
    router = _router(sim_stack, "least-loaded")
    # a heavy video loads replica 0; light texts then fill 1 and 2 first
    first = router._route(_mk("v", Modality.VIDEO, text=32, mm=196 * 64))
    assert first == 0
    assert router._route(_mk("t1")) == 1
    assert router._route(_mk("t2")) == 2
    # the video's estimated prefill dominates: replica 0 is picked last
    assert router._load[0] > router._load[1] > 0
    nxt = router._route(_mk("t3"))
    assert nxt in (1, 2) and nxt != 0


def test_router_truck_isolation_pools(sim_stack):
    from repro.serving.request import Modality
    router = _router(sim_stack, "truck-isolation")  # replica 2 is heavy
    truck = _mk("truck", Modality.VIDEO, text=32, mm=196 * 96)
    moto = _mk("moto", text=32)
    assert router._route(truck) == 2          # trucks pinned to heavy pool
    assert router._route(moto) in (0, 1)      # motorcycles never on heavy
    for i in range(20):
        assert router._route(_mk(f"m{i}", text=32)) != 2
    for i in range(5):
        assert router._route(
            _mk(f"t{i}", Modality.VIDEO, text=32, mm=196 * 96)) == 2


def test_router_unknown_policy_raises(sim_stack):
    router = _router(sim_stack, "no-such-routing")
    with pytest.raises(ValueError):
        router._route(_mk("x"))


def test_router_conserves_and_isolates(sim_stack):
    from repro.serving.executors import SimExecutor
    from repro.serving.router import Router
    executor, classifier, engine_cfg, _, _ = sim_stack
    router = Router(executors=[SimExecutor(executor.cm),
                               SimExecutor(executor.cm)],
                    classifier=classifier, engine_cfg=EngineConfig(),
                    routing="truck-isolation")
    reqs = generate(WorkloadConfig(mix="MH", rate=4.0, num_requests=60,
                                   seed=17))
    done = router.run(reqs)
    assert len(done) + sum(len(e.rejected) for e in router.engines) == 60
    # no truck may land on the light replica
    light = router.engines[0]
    assert all(r.vclass is not VehicleClass.TRUCK for r in light.finished)
