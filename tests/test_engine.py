"""Engine integration + property tests: continuous batching, chunked
prefill, preemption, allocator safety, end-to-end behaviour."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cache.allocator import BlockAllocator, OutOfPages
from repro.core.scheduler import make_policy
from repro.launch.serve import build_stack, serve
from repro.serving.engine import Engine, EngineConfig
from repro.serving.metrics import summarize
from repro.serving.request import State, VehicleClass
from repro.serving.workload import WorkloadConfig, generate


# ---------------- allocator property tests ----------------------------------

@settings(max_examples=30, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 9), st.integers(1, 400),
                              st.booleans()), max_size=60))
def test_allocator_invariants_hold(ops):
    """Random allocate/free sequences never double-allocate or leak pages."""
    alloc = BlockAllocator(num_pages=64, page_size=16)
    for rid_i, tokens, do_free in ops:
        rid = f"r{rid_i}"
        if do_free:
            alloc.free(rid)
        else:
            try:
                alloc.allocate(rid, tokens)
            except OutOfPages:
                pass
        alloc.check_invariants()


def test_allocator_accounting():
    alloc = BlockAllocator(num_pages=10, page_size=16)
    alloc.allocate("a", 33)       # 3 pages
    assert alloc.used_pages == 3
    alloc.allocate("a", 40)       # grow to 3 pages total (ceil(40/16)=3)
    assert alloc.used_pages == 3
    alloc.allocate("a", 49)       # grow to 4
    assert alloc.used_pages == 4
    assert not alloc.can_allocate(16 * 7)
    assert alloc.free("a") == 4
    assert alloc.free_pages == 10


# ---------------- engine end-to-end -----------------------------------------

@pytest.fixture(scope="module")
def sim_stack():
    return build_stack("chatglm3-6b", "sim", model_preset="llava-7b")


@pytest.mark.parametrize("policy", ["fcfs", "edf", "static", "naive-aging",
                                    "tcm"])
def test_engine_completes_all_requests(policy, sim_stack):
    executor, classifier, engine_cfg, _, _ = sim_stack
    eng = Engine(make_policy(policy), executor, classifier, engine_cfg)
    reqs = generate(WorkloadConfig(mix="MH", rate=2.0, num_requests=60, seed=3))
    done = eng.run(reqs)
    assert len(done) == 60
    for r in done:
        assert r.state == State.FINISHED
        assert r.first_token_time is not None
        assert r.finish_time >= r.first_token_time
        assert r.ttft() >= 0
        assert r.decoded >= r.output_tokens
    eng.allocator.check_invariants()
    assert eng.allocator.used_pages == 0  # everything freed


def test_engine_time_monotone_and_ttft_after_arrival(sim_stack):
    executor, classifier, engine_cfg, _, _ = sim_stack
    eng = Engine(make_policy("tcm"), executor, classifier, engine_cfg)
    reqs = generate(WorkloadConfig(mix="MH", rate=4.0, num_requests=40, seed=5))
    done = eng.run(reqs)
    for r in done:
        assert r.first_token_time >= r.arrival
        assert r.first_token_time >= r.ready_at  # preprocess precedes prefill


def test_memory_pressure_preempts_rejects_and_completes(sim_stack):
    executor, classifier, _, _, _ = sim_stack
    cfg = EngineConfig(token_budget=512, kv_pages=1024)  # ~16k tokens only
    eng = Engine(make_policy("fcfs"), executor, classifier, cfg)
    reqs = generate(WorkloadConfig(mix="MH", rate=2.0, num_requests=40, seed=9))
    done = eng.run(reqs)
    # over-capacity videos rejected by admission control; the rest complete
    assert len(done) + len(eng.rejected) == 40
    assert all(r.prompt_tokens + r.output_tokens > 1024 * 16 * 0.9
               for r in eng.rejected)
    assert len(done) >= 30
    eng.allocator.check_invariants()


def test_tcm_zero_motorcycle_preemptions_under_pressure(sim_stack):
    executor, classifier, _, _, _ = sim_stack
    cfg = EngineConfig(token_budget=512, kv_pages=1536)
    eng = Engine(make_policy("tcm"), executor, classifier, cfg)
    reqs = generate(WorkloadConfig(mix="MH", rate=2.5, num_requests=60, seed=11))
    done = eng.run(reqs)
    s = summarize(done)
    assert s["motorcycle"]["preemptions"] == 0


def test_tcm_beats_fcfs_on_motorcycle_ttft(sim_stack):
    executor, classifier, engine_cfg, _, _ = sim_stack
    results = {}
    for pol in ["fcfs", "tcm"]:
        eng = Engine(make_policy(pol), executor, classifier, engine_cfg)
        reqs = generate(WorkloadConfig(mix="MH", rate=2.0, num_requests=80,
                                       seed=13, video_frames_max=96))
        results[pol] = summarize(eng.run(reqs))
    assert results["tcm"]["motorcycle"]["ttft_avg"] < \
        0.6 * results["fcfs"]["motorcycle"]["ttft_avg"]


def test_requests_conserved_through_engine(sim_stack):
    """No request is lost or duplicated across queue/prefill/run/finish."""
    executor, classifier, _, _, _ = sim_stack
    cfg = EngineConfig(token_budget=512, kv_pages=2048)
    eng = Engine(make_policy("tcm"), executor, classifier, cfg)
    reqs = generate(WorkloadConfig(mix="MH", rate=3.0, num_requests=50, seed=21))
    pending = sorted(reqs, key=lambda r: r.arrival)
    seen_finished = set()
    for _ in range(200000):
        pending = eng.step(pending)
        ids = ([r.rid for r in pending] + [r.rid for r in eng.queues.peek_all()]
               + [r.rid for r in eng.prefilling] + [r.rid for r in eng.running]
               + [r.rid for r in eng.finished])
        assert len(ids) == len(set(ids)) == 50
        seen_finished = {r.rid for r in eng.finished}
        if len(seen_finished) == 50:
            break
    assert len(seen_finished) == 50


# ---------------- real-JAX executor end-to-end ------------------------------

def test_engine_with_real_model_executor():
    """Engine over the actual reduced JAX model (proves the full stack)."""
    done, eng = serve(
        "chatglm3-6b", "tcm",
        WorkloadConfig(mix="ML", rate=50.0, num_requests=6, seed=1,
                       out_tokens_log_mu=1.5, out_tokens_log_sigma=0.2,
                       text_tokens_log_mu=3.0, text_tokens_log_sigma=0.5,
                       video_frames_min=1, video_frames_max=2,
                       image_patches=32, video_patches_per_frame=16),
        executor_kind="real")
    assert len(done) == 6
    for r in done:
        assert r.state == State.FINISHED
        assert r.ttft() is not None


# ---------------- multi-replica router ---------------------------------------

def test_router_conserves_and_isolates(sim_stack):
    from repro.serving.executors import SimExecutor
    from repro.serving.router import Router
    executor, classifier, engine_cfg, _, _ = sim_stack
    router = Router(executors=[SimExecutor(executor.cm),
                               SimExecutor(executor.cm)],
                    classifier=classifier, engine_cfg=EngineConfig(),
                    routing="truck-isolation")
    reqs = generate(WorkloadConfig(mix="MH", rate=4.0, num_requests=60,
                                   seed=17))
    done = router.run(reqs)
    assert len(done) + sum(len(e.rejected) for e in router.engines) == 60
    # no truck may land on the light replica
    light = router.engines[0]
    assert all(r.vclass is not VehicleClass.TRUCK for r in light.finished)
