"""Property tests for the incremental scheduling core (core/ordering.py).

Every structure must reproduce its brute-force oracle bit-for-bit:
  * WaitingIndex vs ``sorted(waiting, key=policy.rank)`` (the seed's order)
  * VictimView  vs ``policy.pick_victim`` (max-rank with bar/eligibility)
  * QueueManager O(1) remove preserves FCFS within class
  * full engine: legacy_scheduling=True vs incremental — identical finish
    order, TTFT, finish times, and iteration counts, allocator invariants
    after randomized admit/preempt/finish sequences
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

# session-cached stack shared with the other serving test modules; the
# plain-callable form exists because @given tests (the shim has no fixture
# support) cannot take fixtures
from conftest import sim_stack_cached as _sim_stack

from repro.core.queues import QueueManager
from repro.core.scheduler import make_policy
from repro.serving.engine import Engine, EngineConfig
from repro.serving.request import Modality, Request, State, VehicleClass

POLICIES = ["fcfs", "edf", "static", "naive-aging", "tcm"]
CLASSES = list(VehicleClass)


def _req(i, arrival, vclass, *, slo=10.0, ready=None, prompt=64):
    r = Request(rid=f"r{i:04d}", modality=Modality.TEXT, arrival=arrival,
                text_tokens=prompt, prompt_tokens=prompt, output_tokens=8)
    r.vclass = vclass
    r.slo = slo
    r.ready_at = arrival if ready is None else ready
    r.est_prefill = 0.01 * prompt
    return r


def _drain(index, now):
    """All candidates the index would serve at `now`, without consuming."""
    index.begin_plan(now)
    out = []
    while True:
        head = index.next_candidate(now)
        if head is None:
            break
        out.append(head[1])
    index.end_plan()
    return out


# ---------------- waiting order vs brute-force oracle ------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_waiting_index_matches_sorted_oracle(seed):
    rng = np.random.default_rng(seed)
    for pol_name in POLICIES:
        pol = make_policy(pol_name)
        qm = QueueManager()
        qm.listener = pol.make_waiting_index()
        now = 0.0
        live = []
        for i in range(60):
            now += float(rng.uniform(0.0, 0.5))
            if live and rng.uniform() < 0.25:  # admit (remove) a random one
                victim = live.pop(int(rng.integers(len(live))))
                qm.remove(victim)
            arrival = now - float(rng.uniform(0.0, 2.0))
            ready = arrival + float(rng.uniform(0.0, 3.0))
            r = _req(i, arrival, CLASSES[int(rng.integers(3))],
                     slo=float(rng.uniform(1, 30)), ready=ready)
            qm.push(r, now)
            live.append(r)
            if rng.uniform() < 0.4:
                # the engine clock (and thus the index's query clock) is
                # monotone, so advance `now` to the query time
                now = now_q = now + float(rng.uniform(0.0, 1.0))
                oracle = pol.order(
                    [r for r in qm.peek_all() if r.ready_at <= now_q], now_q)
                got = _drain(qm.listener, now_q)
                assert [r.rid for r in got] == [r.rid for r in oracle], \
                    f"{pol_name} diverged from sorted oracle @ step {i}"
        # drawing must be non-destructive: a second drain is identical
        final = _drain(qm.listener, now + 1.0)
        again = _drain(qm.listener, now + 1.0)
        assert [r.rid for r in final] == [r.rid for r in again]


def test_waiting_index_excludes_pushes_during_plan():
    pol = make_policy("tcm")
    qm = QueueManager()
    idx = qm.listener = pol.make_waiting_index()
    a = _req(0, 0.0, VehicleClass.CAR)
    qm.push(a, 1.0)
    idx.begin_plan(2.0)
    assert idx.next_candidate(2.0)[1] is a
    b = _req(1, 0.0, VehicleClass.MOTORCYCLE)
    qm.push(b, 2.0)  # mid-plan push (preemption requeue): snapshot excludes
    assert idx.next_candidate(2.0) is None
    idx.end_plan()
    assert [r.rid for r in _drain(idx, 3.0)].count(b.rid) == 1


# ---------------- FCFS preserved through O(1) removal ------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_queue_manager_fcfs_within_class_after_removals(seed):
    rng = np.random.default_rng(seed)
    qm = QueueManager()
    reference = {v: [] for v in CLASSES}
    now = 0.0
    for i in range(80):
        now += float(rng.uniform(0, 0.3))
        v = CLASSES[int(rng.integers(3))]
        r = _req(i, now, v)
        qm.push(r, now)
        reference[v].append(r)
        if rng.uniform() < 0.35:
            vv = CLASSES[int(rng.integers(3))]
            if reference[vv]:
                gone = reference[vv].pop(int(rng.integers(len(reference[vv]))))
                qm.remove(gone)
    for v in CLASSES:
        assert [r.rid for r in qm.queues[v]] == \
            [r.rid for r in reference[v]], "FCFS order broken by remove"
        assert len(qm.queues[v]) == len(reference[v])
    assert len(qm) == sum(len(x) for x in reference.values())
    m = qm.metrics(now)
    for v in CLASSES:
        waits = [r.waiting_time(now) for r in reference[v]]
        if waits:
            assert m[v.value]["avg_wait"] == \
                pytest.approx(sum(waits) / len(waits))
        assert m[v.value]["est_prefill_sum"] == \
            pytest.approx(sum(r.est_prefill for r in reference[v]))


# ---------------- victim view vs pick_victim oracle --------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_victim_view_matches_pick_victim_oracle(seed):
    rng = np.random.default_rng(seed)
    for pol_name in POLICIES:
        pol = make_policy(pol_name)
        now = float(rng.uniform(5, 50))
        pool = []
        for i in range(25):
            r = _req(i, float(rng.uniform(0, now)),
                     CLASSES[int(rng.integers(3))],
                     slo=float(rng.uniform(1, 30)))
            r.enqueue_time = r.arrival
            pool.append(r)
        view = pol.make_victim_view(pool, now)
        # no-bar pick (decode-growth path)
        assert view.pick() is pol.pick_victim(pool, now)
        # bar picks for random admission candidates
        for _ in range(6):
            cand = _req(99, float(rng.uniform(0, now)),
                        CLASSES[int(rng.integers(3))])
            cand.enqueue_time = cand.arrival
            assert view.pick(bar=pol.rank(cand, now)) is \
                pol.pick_victim(pool, now, for_req=cand)
        # incremental add/discard stays consistent with a fresh oracle pool
        extra = _req(50, float(rng.uniform(0, now)),
                     CLASSES[int(rng.integers(3))])
        extra.enqueue_time = extra.arrival
        view.add(extra)
        gone = pool[int(rng.integers(len(pool)))]
        view.discard(gone)
        updated = [r for r in pool if r is not gone] + [extra]
        assert view.pick() is pol.pick_victim(updated, now)
        # preempt-then-readmit at the same clock: the re-added request must
        # be visible again (per-entry staleness, not per-rid)
        back = updated[int(rng.integers(len(updated)))]
        view.discard(back)
        view.add(back)
        assert view.pick() is pol.pick_victim(
            [r for r in updated if r is not back] + [back], now)


# ---------------- engine: legacy vs incremental equivalence ------------------


def _run(policy, stack, *, legacy, n=120, seed=3, kv_pages=2048,
         token_budget=512):
    from repro.serving.workload import WorkloadConfig, generate
    executor, classifier, _, _, _ = stack
    eng = Engine(make_policy(policy), executor, classifier,
                 EngineConfig(token_budget=token_budget, kv_pages=kv_pages,
                              legacy_scheduling=legacy))
    done = eng.run(generate(WorkloadConfig(mix="MH", rate=3.0,
                                           num_requests=n, seed=seed)))
    return done, eng


@pytest.mark.parametrize("policy", POLICIES)
def test_engine_decisions_identical_to_legacy(policy, sim_stack):
    """The tentpole guarantee: incremental structures change the cost of
    scheduling, never its decisions — under memory pressure (kv_pages=2048
    forces preemptions) finish order, TTFT and finish times are bitwise
    equal to the seed's brute-force path."""
    done_new, eng_new = _run(policy, sim_stack, legacy=False)
    done_old, eng_old = _run(policy, sim_stack, legacy=True)
    assert [r.rid for r in done_new] == [r.rid for r in done_old]
    assert [(r.first_token_time, r.finish_time, r.preemptions)
            for r in done_new] == \
           [(r.first_token_time, r.finish_time, r.preemptions)
            for r in done_old]
    assert eng_new.iterations == eng_old.iterations
    assert eng_new.now == eng_old.now


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1_000))
def test_allocator_invariants_after_randomized_engine_run(seed):
    """Randomized admit/preempt/finish sequences (tight KV forces decode-
    growth preemptions) never double-allocate or leak pages."""
    done, eng = _run("tcm", _sim_stack(), legacy=False, n=40, seed=seed,
                     kv_pages=768)
    eng.allocator.check_invariants()
    assert len(done) + len(eng.rejected) == 40
    assert eng.allocator.used_pages == 0
    assert len(eng.wait_index) == 0  # no leaked index entries


def test_page_aligned_prompt_grows_kv_like_seed(sim_stack):
    """prompt_tokens an exact multiple of page_size: the first decode page
    is needed right after prefill, and the allocator trajectory must match
    the seed's allocate-every-token path page for page."""
    executor, classifier, _, _, _ = sim_stack
    engines = {}
    for legacy in (False, True):
        eng = Engine(make_policy("fcfs"), executor, classifier,
                     EngineConfig(legacy_scheduling=legacy, page_size=16))
        r = Request(rid="aligned", modality=Modality.TEXT, arrival=0.0,
                    text_tokens=16, prompt_tokens=16, output_tokens=40)
        pending = [r]
        for _ in range(50):
            pending = eng.step(pending)
            owned = eng.allocator.owned_pages("aligned")
            engines.setdefault(legacy, []).append(owned)
            if eng.finished:
                break
        assert eng.finished
    assert engines[False] == engines[True], \
        "per-iteration page ownership diverged from the seed path"
    assert max(engines[False]) == 4  # 16 prompt + 40 decoded = 4 pages


def test_step_accepts_unsorted_pending(sim_stack):
    """The seed's public step() ingested arrived requests regardless of
    list order; the cursor-based core must not strand them."""
    executor, classifier, _, _, _ = sim_stack
    eng = Engine(make_policy("fcfs"), executor, classifier, EngineConfig())
    late = Request(rid="late", modality=Modality.TEXT, arrival=50.0,
                   text_tokens=8, prompt_tokens=8)
    early = Request(rid="early", modality=Modality.TEXT, arrival=0.0,
                    text_tokens=8, prompt_tokens=8)
    eng.now = 1.0
    remaining = eng.step([late, early])  # unsorted: early hides behind late
    assert [r.rid for r in remaining] == ["late"]
    assert "early" in {r.rid for r in eng.prefilling} | \
        {r.rid for r in eng.queues.peek_all()} | {r.rid for r in eng.running}


# ---------------- decode-growth OutOfPages handling --------------------------

def test_outofpages_exported_from_cache_package():
    from repro.cache import OutOfPages as OOP
    from repro.cache.allocator import OutOfPages as OOP2
    assert OOP is OOP2


def test_decode_growth_with_no_victim_preempts_self(sim_stack):
    """Seed behaviour: an uncaught OutOfPages crashed the engine when no
    victim was eligible for a decode-time page. Now the decoding request
    itself is preempted recompute-style."""
    executor, classifier, _, _, _ = sim_stack
    eng = Engine(make_policy("tcm"), executor, classifier,
                 EngineConfig(kv_pages=2, page_size=16))
    car = _req(0, 0.0, VehicleClass.CAR, prompt=16)
    moto = _req(1, 0.0, VehicleClass.MOTORCYCLE, prompt=16)
    for r, tokens in ((car, 16), (moto, 16)):
        eng.allocator.allocate(r.rid, tokens)
        r.state = State.RUNNING
        r.decoded = 0
        eng.running[r] = None
    assert eng.allocator.free_pages == 0
    # car needs a 2nd page; the only other running request is a motorcycle
    # (never preempted under tcm) -> car itself must be evicted, not crash
    assert eng._grow_kv(car, 17) is False
    assert car.state == State.PREEMPTED
    assert car.preemptions == 1
    assert car in eng.queues.peek_all()
    assert moto in eng.running and car not in eng.running
    eng.allocator.check_invariants()
    assert eng.allocator.owned_pages(moto.rid) == 1
    assert eng.allocator.owned_pages(car.rid) == 0


def test_decode_growth_prefers_eligible_victim(sim_stack):
    executor, classifier, _, _, _ = sim_stack
    eng = Engine(make_policy("fcfs"), executor, classifier,
                 EngineConfig(kv_pages=2, page_size=16))
    a = _req(0, 0.0, VehicleClass.CAR, prompt=16)      # older
    b = _req(1, 5.0, VehicleClass.CAR, prompt=16)      # newer -> victim
    for r in (a, b):
        eng.allocator.allocate(r.rid, 16)
        r.state = State.RUNNING
        eng.running[r] = None
    assert eng._grow_kv(a, 17) is True
    assert b.state == State.PREEMPTED
    assert a in eng.running
    eng.allocator.check_invariants()
