"""Ref-counted KV prefix cache (ISSUE 4): allocator refcount/COW/eviction
invariants, content-chunk hashing, scheduler integration, and the
cache-on/cache-off (and vs ``legacy``) equivalence oracles.

The cache may only change *when* work happens — never what is emitted:
sim runs must finish the same requests with the same decoded work, the
fast scheduling path must stay decision-identical to
``legacy_scheduling``, and the real executor must emit bit-identical
greedy tokens with the cache on, off, and against the dense-slot legacy
oracle."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cache import BlockAllocator, OutOfPages
from repro.cache.allocator import common_prefix_tokens, iter_page_runs
from repro.core.scheduler import make_policy
from repro.serving.engine import Engine, EngineConfig
from repro.serving.executors import SimExecutor
from repro.serving.metrics import summarize
from repro.serving.request import Modality, Request
from repro.serving.workload import WorkloadConfig, generate

# ---------------- page-run hashing ------------------------------------------


def test_iter_page_runs_recuts_chunks_into_pages():
    runs = list(iter_page_runs((("sys:a", 20), ("txt!r", 10)), 16))
    assert runs == [
        ((("sys:a", 0, 16),), 16),
        ((("sys:a", 16, 4), ("txt!r", 0, 10)), 14),
    ]


def test_common_prefix_tokens_spans_runs():
    a = (("sys:a", 0, 8), ("mm:h", 0, 8))
    b = (("sys:a", 0, 8), ("mm:h", 0, 4), ("txt!x", 0, 4))
    assert common_prefix_tokens(a, b) == 12
    assert common_prefix_tokens(a, (("sys:b", 0, 8),)) == 0


def test_content_chunks_layout_and_residual_sizes():
    r = Request(rid="r1", modality=Modality.VIDEO, arrival=0.0,
                text_tokens=30, mm_units=100, prompt_tokens=130,
                mm_hash="h1", shared_prefix_id="p", shared_prefix_tokens=10)
    assert r.content_chunks() == (
        ("sys:p", 10), ("mm:h1", 100), ("txt!r1", 20))
    # cached 50 tokens: covers sys(10) + 40 of the mm payload
    assert r.residual_sizes(50) == (20, 60)
    assert r.residual_sizes(0) == (30, 100)
    assert r.residual_sizes(110) == (20, 0)   # mm fully cached -> "sand"


# ---------------- allocator: match / claim / publish ------------------------


def _alloc(pages=64, page=16):
    return BlockAllocator(num_pages=pages, page_size=page)


def _admit(a, rid, chunks, tokens):
    """Engine-shaped admission: match -> claim -> allocate."""
    m = a.match_prefix(chunks, tokens - 1)
    claimed, cow_dst = a.claim_prefix(rid, m)
    a.allocate(rid, tokens)
    return m, claimed, cow_dst


def test_shared_prefix_matches_not_just_whole_prompt():
    a = _alloc()
    ch_a = (("sys:s", 48), ("txt!a", 48))          # sys = 3 full pages
    _admit(a, "a", ch_a, 96)
    a.publish_prefix("a", ch_a)
    # different request, same system prompt, different length
    ch_b = (("sys:s", 48), ("txt!b", 100))
    m = a.match_prefix(ch_b, 147)
    assert len(m.pages) == 3 and m.tokens == 48 and m.cow_src is None
    assert m.pages == a.pages_of("a")[:3]
    a.check_invariants()


def test_cow_donor_on_partially_shared_boundary_page():
    a = _alloc()
    ch_a = (("sys:s", 40), ("txt!a", 30))   # sys ends mid-page-2 (40=2p+8)
    _admit(a, "a", ch_a, 70)
    a.publish_prefix("a", ch_a)
    ch_b = (("sys:s", 40), ("txt!b", 60))
    m = a.match_prefix(ch_b, 99)
    assert len(m.pages) == 2 and m.cow_valid == 8 and m.tokens == 40
    assert m.cow_src == a.pages_of("a")[2]
    _, claimed, cow_dst = _admit(a, "b", ch_b, 100)
    assert claimed == 40 and cow_dst is not None
    # b's block table: 2 shared pages, then the private COW copy
    assert a.pages_of("b")[:2] == m.pages and a.pages_of("b")[2] == cow_dst
    assert a.ref_count(m.pages[0]) == 2 and a.ref_count(cow_dst) == 1
    a.check_invariants()


def test_exact_duplicate_caps_at_prompt_minus_one():
    """The last prompt token must run through the model (its logits emit
    the first output token), so a whole-prompt duplicate claims at most
    prompt-1 tokens — via COW on the final page."""
    a = _alloc()
    ch = (("mm:h", 64),)                      # exactly 4 pages
    _admit(a, "a", ch, 64)
    a.publish_prefix("a", ch)
    m = a.match_prefix(ch, 63)
    assert len(m.pages) == 3 and m.cow_valid == 15 and m.tokens == 63
    a.check_invariants()


def test_private_content_is_never_indexed():
    a = _alloc()
    ch = (("txt!a", 100),)
    _admit(a, "a", ch, 100)
    a.publish_prefix("a", ch)
    assert a.cached_pages == 0 and a.prefix_stats()["published_pages"] == 0
    a.free("a")
    assert a.free_pages == a.num_pages    # nothing lingers
    a.check_invariants()


def test_publish_stops_at_first_private_page_after_cow_donor():
    a = _alloc()
    ch = (("sys:s", 40), ("txt!a", 60))
    _admit(a, "a", ch, 100)
    a.publish_prefix("a", ch)
    # pages 0,1 full-sys chain + page 2 as COW donor; 3.. stay private
    assert a.cached_pages == 3
    a.check_invariants()


def test_freeing_one_owner_never_frees_shared_pages():
    a = _alloc()
    ch_a = (("sys:s", 64), ("txt!a", 10))
    _admit(a, "a", ch_a, 74)
    a.publish_prefix("a", ch_a)
    ch_b = (("sys:s", 64), ("txt!b", 10))
    m, claimed, _ = _admit(a, "b", ch_b, 74)
    shared = m.pages
    a.free("a")    # preemption/finish of the publisher
    a.check_invariants()
    assert all(a.ref_count(p) == 1 for p in shared)   # b still holds them
    assert all(p not in a._free for p in shared)
    a.free("b")
    a.check_invariants()
    # now zero-ref but cached: evictable, counted available, not free
    assert all(a.ref_count(p) == 0 for p in shared)
    assert a.evictable_pages == 4 and a.available_pages == a.num_pages


def test_zero_ref_cached_pages_count_as_free_and_evict_lru():
    a = _alloc(pages=8, page=16)
    _admit(a, "a", (("sys:s", 48), ("txt!a", 16)), 64)
    a.publish_prefix("a", (("sys:s", 48), ("txt!a", 16)))
    a.free("a")
    assert a.free_pages == 5 and a.evictable_pages == 3  # (no donor: page 3
    #                       is a pure private page -> freed, sys chain cached)
    assert a.can_allocate(8 * 16)      # evictables count as allocatable
    a.allocate("big", 8 * 16)          # forces eviction of the chain
    a.check_invariants()
    assert a.cached_pages == 0 and a.prefix_stats()["evictions"] == 3
    with pytest.raises(OutOfPages):
        a.allocate("more", 16)


def test_eviction_is_lru_over_chains():
    a = _alloc(pages=6, page=16)
    for rid, sid in (("a", "sys:x"), ("b", "sys:y")):
        ch = ((sid, 32), (f"txt!{rid}", 8))
        _admit(a, rid, ch, 40)
        a.publish_prefix(rid, ch)
        a.free(rid)
    # both 2-page chains cached; touch x by re-claiming it, then demand
    # more fresh pages than the free list holds
    m = a.match_prefix((("sys:x", 32), ("txt!c", 8)), 39)
    a.claim_prefix("c", m)
    a.allocate("c", 80)       # 3 fresh pages, 2 free -> evicts the colder
    a.check_invariants()      # y chain (x is referenced, never evicted)
    assert a.match_prefix((("sys:y", 32), ("txt!d", 8)), 39).tokens == 0
    assert a.match_prefix((("sys:x", 32), ("txt!d", 8)), 39).tokens == 32


def test_can_allocate_is_rid_aware():
    """Satellite regression: a growth check for a request that already
    owns pages must mirror ``allocate``'s incremental need, not demand
    room for the whole context again."""
    a = _alloc(pages=4, page=16)
    a.allocate("r", 48)                 # owns 3 of 4 pages
    assert not a.can_allocate(64)       # rid-unaware: 4 needed, 1 free
    assert a.can_allocate(64, rid="r")  # incremental: 1 more page
    a.allocate("r", 64)                 # ...and allocate agrees
    assert not a.can_allocate(16)
    a.check_invariants()


# ---------------- allocator: property test ----------------------------------

_SYS = [None, ("sys:alpha", 40), ("sys:beta", 96), ("mm:vid0", 200)]


def _chunks(rid: str, variant: int, tokens: int):
    shared = _SYS[variant % len(_SYS)]
    chunks = []
    if shared is not None:
        chunks.append((shared[0], min(shared[1], tokens)))
    rest = tokens - sum(n for _c, n in chunks)
    if rest > 0:
        chunks.append((f"txt!{rid}", rest))
    return tuple(chunks)


@settings(max_examples=40, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 5), st.integers(0, 3),
                              st.integers(1, 300), st.integers(0, 3)),
                    max_size=50))
def test_refcount_invariants_under_random_schedules(ops):
    """Random admit/publish/grow/free schedules keep refcount
    conservation, the free/owned/cached partition, and the trie
    well-formed — and never double-free a shared page."""
    a = BlockAllocator(num_pages=48, page_size=16)
    live: dict[str, tuple] = {}
    for rid_i, variant, tokens, action in ops:
        rid = f"r{rid_i}"
        if action == 0 and rid not in live:          # admit
            chunks = _chunks(rid, variant, tokens)
            m = a.match_prefix(chunks, tokens - 1)
            if a.can_allocate(tokens, rid=rid, match=m):
                a.claim_prefix(rid, m)
                try:
                    a.allocate(rid, tokens)
                    live[rid] = (chunks, tokens)
                except OutOfPages:      # stale match accounting would leak
                    a.free(rid)
        elif action == 1 and rid in live:            # publish (prefill done)
            a.publish_prefix(rid, live[rid][0])
        elif action == 2 and rid in live:            # decode growth
            try:
                a.allocate(rid, live[rid][1] + tokens)
            except OutOfPages:
                pass
        elif action == 3:                            # preempt/finish
            a.free(rid)
            live.pop(rid, None)
        a.check_invariants()
    for rid in list(live):
        a.free(rid)
    a.check_invariants()
    assert a.available_pages == a.num_pages


# ---------------- workload satellite ----------------------------------------


def _req_tuple(r: Request):
    return (r.rid, r.modality, r.arrival, r.text_tokens, r.mm_units,
            r.output_tokens, r.prompt_tokens, r.mm_hash,
            r.shared_prefix_id, r.shared_prefix_tokens)


def test_shared_prefix_prob_zero_is_byte_identical():
    base = [_req_tuple(r) for r in generate(
        WorkloadConfig(mix="MH", num_requests=80, seed=7))]
    again = [_req_tuple(r) for r in generate(
        WorkloadConfig(mix="MH", num_requests=80, seed=7,
                       shared_prefix_prob=0.0))]
    assert base == again


def test_shared_prefix_prob_attaches_pool_prompts():
    reqs = generate(WorkloadConfig(mix="T0", num_requests=200, seed=3,
                                   shared_prefix_prob=0.5,
                                   shared_prefix_pool=3))
    tagged = [r for r in reqs if r.shared_prefix_id]
    assert 40 < len(tagged) < 160
    assert len({r.shared_prefix_id for r in tagged}) <= 3
    # same id => same length (content identity), prompt includes it
    by_id: dict = {}
    for r in tagged:
        by_id.setdefault(r.shared_prefix_id, set()).add(
            r.shared_prefix_tokens)
        assert r.prompt_tokens == r.text_tokens > r.shared_prefix_tokens
    assert all(len(v) == 1 for v in by_id.values())


# ---------------- engine integration (sim) ----------------------------------

_WL = dict(mix="MH", rate=2.5, num_requests=90, seed=17,
           duplicate_prob=0.4, shared_prefix_prob=0.5)


def _run_engine(classifier, cm, *, cache=True, legacy_sched=False,
                kv_pages=24576, residual=True):
    ex = SimExecutor(cm)
    eng = Engine(make_policy("tcm"), ex, classifier,
                 EngineConfig(token_budget=512, kv_pages=kv_pages,
                              prefix_cache=cache,
                              prefix_residual_classify=residual,
                              legacy_scheduling=legacy_sched))
    done = eng.run(generate(WorkloadConfig(**_WL)))
    eng.allocator.check_invariants()
    return done, eng, ex


def test_cache_on_skips_prefill_work_but_changes_no_outputs(sim_stack):
    executor, classifier, *_ = sim_stack
    on, eng_on, ex_on = _run_engine(classifier, executor.cm, cache=True)
    off, eng_off, ex_off = _run_engine(classifier, executor.cm, cache=False)
    assert len(on) == len(off) == _WL["num_requests"]
    # identical per-request outputs: same decode work for every rid
    assert {r.rid: r.decoded for r in on} == \
        {r.rid: r.decoded for r in off}
    assert eng_on.allocator.prefix_hits > 0
    assert ex_on.prefill_tokens < ex_off.prefill_tokens
    assert sum(r.cached_prefix_tokens for r in on) == \
        eng_on.allocator.prefix_tokens_served
    s_on, s_off = summarize(on), summarize(off)
    assert s_on["overall"]["ttft_avg"] < s_off["overall"]["ttft_avg"]


def test_fast_path_decisions_match_legacy_scheduling_with_cache_on(
        sim_stack):
    """PR-1's equivalence oracle must survive the prefix cache: the
    incremental planner and the brute-force legacy_scheduling path share
    the allocator, so their decisions stay bit-identical with hits,
    claims, and evictions in play."""
    executor, classifier, *_ = sim_stack
    fast, eng_f, _ = _run_engine(classifier, executor.cm, kv_pages=2048)
    legc, eng_l, _ = _run_engine(classifier, executor.cm, kv_pages=2048,
                                 legacy_sched=True)
    assert [r.rid for r in fast] == [r.rid for r in legc]
    assert [(r.ttft(), r.finish_time, r.preemptions,
             r.cached_prefix_tokens) for r in fast] == \
        [(r.ttft(), r.finish_time, r.preemptions,
          r.cached_prefix_tokens) for r in legc]
    assert eng_f.iterations == eng_l.iterations
    assert eng_f.allocator.prefix_stats() == eng_l.allocator.prefix_stats()


def test_duplicate_video_reclassifies_rock_to_sand(sim_stack):
    """The headline scheduler effect: a video whose prompt is almost
    entirely cached KV has the residual prefill of sand — the classifier
    must stop calling it a truck, and its SLO must tighten to match."""
    executor, classifier, *_ = sim_stack
    video = dict(modality=Modality.VIDEO, text_tokens=32,
                 mm_units=40 * 196, output_tokens=64, mm_hash="dup-vid")
    # the duplicate arrives mid-way through the original's run: its
    # ingest makes the content popular, the original publishes at prefill
    # completion (or retro-publishes if already decoding), and the
    # duplicate claims + re-prices at admission. max_num_seqs=1 forces
    # the admission to queue behind the original — the contended regime
    # prefix caching exists for.
    r1 = Request(rid="v1", arrival=0.0,
                 prompt_tokens=32 + 40 * 196, **video)
    r2 = Request(rid="v2", arrival=0.5,
                 prompt_tokens=32 + 40 * 196, **video)
    ex = SimExecutor(executor.cm)
    eng = Engine(make_policy("tcm"), ex, classifier,
                 EngineConfig(token_budget=512, max_num_seqs=1))
    done = eng.run([r1, r2])
    assert len(done) == 2
    assert r1.vclass.value == "truck" and r1.cached_prefix_tokens == 0
    assert r2.cached_prefix_tokens > 0.9 * r2.mm_units
    assert r2.vclass.value != "truck"          # rock -> sand priority
    assert r2.est_prefill < 0.1 * r1.est_prefill
    assert r2.slo < r1.slo                     # residual-prefill SLO
    # the duplicate's prefill stage collapses (its TTFT is queue wait)
    assert r2.ttft_breakdown()["prefill"] < \
        0.1 * r1.ttft_breakdown()["prefill"]
    # ablation: residual classification off keeps the truck label (the
    # pages are still shared, only the ranking ignores it)
    ex3 = SimExecutor(executor.cm)
    eng3 = Engine(make_policy("tcm"), ex3, classifier,
                  EngineConfig(token_budget=512, max_num_seqs=1,
                               prefix_residual_classify=False))
    r3 = Request(rid="v3", arrival=0.0, prompt_tokens=32 + 40 * 196, **video)
    r4 = Request(rid="v4", arrival=0.5, prompt_tokens=32 + 40 * 196, **video)
    eng3.run([r3, r4])
    assert r4.vclass.value == "truck" and r4.cached_prefix_tokens > 0


def test_preempted_request_reclaims_its_own_published_chain(sim_stack):
    """Recompute-style preemption after a completed prefill: the evicted
    pages stay cached, so re-admission claims them back and the re-prefill
    is (nearly) free."""
    executor, classifier, *_ = sim_stack
    ex = SimExecutor(executor.cm)
    eng = Engine(make_policy("tcm"), ex, classifier, EngineConfig())
    big = Request(rid="vid", modality=Modality.VIDEO, arrival=0.0,
                  text_tokens=32, mm_units=30 * 196, output_tokens=64,
                  prompt_tokens=32 + 30 * 196, mm_hash="h-self")
    pending = [big]
    while big.state.value != "running":
        pending = eng.step(pending)
    eng._preempt(big)
    assert eng.allocator.owned_pages("vid") == 0
    assert eng.allocator.evictable_pages > 0   # chain survived eviction
    for _ in range(100000):
        pending = eng.step(pending)
        if big.state.value == "finished":
            break
    assert big.state.value == "finished" and big.preemptions == 1
    assert big.cached_prefix_tokens > 0.9 * big.mm_units
    eng.allocator.check_invariants()


# ---------------- real executor parity (acceptance) --------------------------


def test_real_executor_token_parity_cache_on_off_legacy():
    """Acceptance: bit-identical emitted tokens with the prefix cache on
    vs off vs the dense-slot ``legacy=True`` oracle, on a duplicate- and
    shared-prefix-heavy multimodal mix with a forced preemption. The
    scenario lives in benchmarks/prefix_cache.py (the CI regression gate
    re-runs the same function) — one source of truth, not a drifting
    copy."""
    from benchmarks.prefix_cache import measure_real_parity
    result = measure_real_parity()
    assert result["token_parity"]
    assert result["prefix_hits_on"] > 0


def test_real_executor_cow_page_copy_is_bit_exact():
    """The jitted donor->private page copy (``PagedStackStore.copy_page``
    across every layer stack): a request resuming prefill mid-page on a
    COW copy must emit exactly the tokens it would have emitted
    prefilling its whole prompt from scratch."""
    from repro.configs import get_reduced
    from repro.serving.executors import ExecutorConfig, ModelExecutor

    def _mk(rid, prompt, out=4):
        return Request(rid=rid, modality=Modality.TEXT, arrival=0.0,
                       text_tokens=prompt, prompt_tokens=prompt,
                       output_tokens=out, shared_prefix_id="cow",
                       shared_prefix_tokens=24)   # ends mid-page (24=p+8)

    def _drive(ex, alloc, req, claim=None):
        if claim is not None:
            tokens, cow_src, cow_dst = claim
            ex.on_prefix_claim(req, tokens, cow_src, cow_dst)
            req.prefilled = tokens
        alloc.allocate(req.rid, req.prompt_tokens + req.output_tokens + 2)
        req.state = State.PREFILLING
        ex.run_iteration([(req, req.prompt_tokens - req.prefilled)],
                         [], [])
        req.prefilled = req.prompt_tokens
        req.state = State.RUNNING
        req.decoded = 1
        while req.decoded < req.output_tokens:
            ex.run_iteration([], [req], [])
            req.decoded += 1
        return list(ex.emitted[req.rid])

    from repro.cache import BlockAllocator
    from repro.serving.request import State
    cfg = get_reduced("chatglm3-6b")
    ex = ModelExecutor(cfg, ExecutorConfig(max_slots=4, max_len=128))
    alloc = BlockAllocator(num_pages=ex.allocator.num_pages, page_size=16)
    ex.bind_allocator(alloc)
    donor = _mk("cowA", 40)
    got_a = _drive(ex, alloc, donor)
    alloc.publish_prefix("cowA", donor.content_chunks())
    dup = _mk("cowB", 56)
    m = alloc.match_prefix(dup.content_chunks(), dup.prompt_tokens - 1)
    assert len(m.pages) == 1 and m.cow_valid == 8 and m.tokens == 24
    claimed, cow_dst = alloc.claim_prefix("cowB", m)
    got_b = _drive(ex, alloc, dup, claim=(claimed, m.cow_src, cow_dst))
    alloc.check_invariants()
    # oracle: the same request prefilled from scratch on a fresh executor
    ex2 = ModelExecutor(cfg, ExecutorConfig(max_slots=4, max_len=128))
    alloc2 = BlockAllocator(num_pages=ex2.allocator.num_pages,
                            page_size=16)
    ex2.bind_allocator(alloc2)
    ref_b = _drive(ex2, alloc2, _mk("cowB", 56))
    assert got_b == ref_b
    assert got_a == _drive(ex2, alloc2, _mk("cowA", 40))


def test_model_executor_content_streams_share_prefix_tokens():
    """Requests carrying the same content id get identical token values
    there (the KV a shared page holds really is interchangeable), while
    fully-private prompts keep the historical rid-seeded stream."""
    import zlib

    from repro.configs import get_reduced
    from repro.serving.executors import ExecutorConfig, ModelExecutor
    ex = ModelExecutor(get_reduced("chatglm3-6b"),
                       ExecutorConfig(max_slots=2, max_len=64))
    a = Request(rid="a", modality=Modality.TEXT, arrival=0.0,
                text_tokens=40, prompt_tokens=40,
                shared_prefix_id="s", shared_prefix_tokens=24)
    b = Request(rid="b", modality=Modality.TEXT, arrival=0.0,
                text_tokens=48, prompt_tokens=48,
                shared_prefix_id="s", shared_prefix_tokens=24)
    ta, tb = ex._prompt_tokens(a), ex._prompt_tokens(b)
    np.testing.assert_array_equal(ta[:24], tb[:24])
    assert not np.array_equal(ta[24:40], tb[24:40])
    plain = Request(rid="p", modality=Modality.TEXT, arrival=0.0,
                    text_tokens=12, prompt_tokens=12)
    seed = zlib.crc32(b"p") & 0x7FFFFFFF
    np.testing.assert_array_equal(
        ex._prompt_tokens(plain),
        np.random.default_rng(seed).integers(1, ex.cfg.vocab_size, size=12,
                                             dtype=np.int64))
