"""Beyond-paper serving optimization: adaptive prefill chunking
(decode-priority) — cap the prefill share of an iteration while
latency-critical requests are decoding. EXPERIMENTS §Serving-perf."""
from repro.core.scheduler import make_policy
from repro.serving.engine import Engine, EngineConfig
from repro.serving.metrics import summarize
from repro.serving.workload import WorkloadConfig, generate

from .common import csv_row, stack


def main(fast: bool = False):
    rows = []
    n = 150 if fast else 300
    ex, _, smart, _ = stack("llava-7b")
    print("variant,class,ttft_avg,norm_lat,viol_rate")
    for name, dp in [("tcm", False), ("tcm+decode-priority", True)]:
        eng = Engine(make_policy("tcm"), ex, smart,
                     EngineConfig(token_budget=512, decode_priority=dp))
        reqs = generate(WorkloadConfig(mix="MH", rate=2.0, num_requests=n,
                                       seed=7, video_frames_max=96))
        s = summarize(eng.run(reqs))
        for g in ["motorcycle", "car", "truck", "overall"]:
            print(f"{name},{g},{s[g]['ttft_avg']:.3f},"
                  f"{s[g]['norm_latency_avg']:.4f},"
                  f"{s[g]['slo_violation_rate']:.3f}")
        rows.append(csv_row(f"beyond_{name}_overall_viol",
                            s["overall"]["slo_violation_rate"]))
        if dp:
            assert s["motorcycle"]["slo_violation_rate"] < 0.10
    return rows


if __name__ == "__main__":
    main()
