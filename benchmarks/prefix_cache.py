"""KV prefix cache benchmark (ISSUE 4 tentpole metric).

Measures what ref-counted page sharing buys on a duplicate- and
shared-prefix-heavy MH mix (``duplicate_prob`` repeats mm inputs,
``shared_prefix_prob`` makes text requests open with pooled system
prompts):

  * cache on vs off — prefill tokens actually executed (the headline:
    duplicate rocks prefill once), mean/p99 TTFT, per-class TTFT, and the
    allocator's hit/COW/eviction counters;
  * rock→sand re-classification ablation — cache on but the classifier
    and SLOs ranking by *full* rather than residual prefill
    (``prefix_residual_classify=False``), isolating how much of the win
    is scheduling (priority) rather than skipped compute;
  * equivalence before speed — the sim runs must finish identical
    request sets with identical decode work, and a real-`ModelExecutor`
    mini-mix (with a forced preemption) must emit bit-identical greedy
    tokens with the cache on, off, and on the ``legacy=True`` dense-slot
    oracle. Both are asserted before any speedup is reported.

Sim numbers are deterministic on fixed seeds; the full mode writes
``BENCH_prefix.json`` (the committed baseline that
benchmarks/check_regression.py re-derives — exact on parity and hit
counts, tolerant on float metrics):

    PYTHONPATH=src python -m benchmarks.run --only prefix_cache [--fast]
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import csv_row, pctl, stack
from repro.core.scheduler import make_policy
from repro.serving.engine import Engine, EngineConfig
from repro.serving.executors import SimExecutor
from repro.serving.metrics import summarize
from repro.serving.workload import WorkloadConfig, generate

MODEL = "llava-7b"
POLICY = "tcm"
NUM_REQUESTS = 300
SEED = 11
RATE = 4.0          # bursty enough that duplicates overlap their originals
DUPLICATE_PROB = 0.5
SHARED_PREFIX_PROB = 0.6
BASELINE_PATH = Path(__file__).resolve().parent.parent / \
    "BENCH_prefix.json"


def _workload() -> WorkloadConfig:
    return WorkloadConfig(mix="MH", rate=RATE, num_requests=NUM_REQUESTS,
                          seed=SEED, video_frames_max=96,
                          duplicate_prob=DUPLICATE_PROB,
                          shared_prefix_prob=SHARED_PREFIX_PROB)


def _engine_run(classifier, cm, *, cache=True, residual=True,
                legacy_sched=False):
    ex = SimExecutor(cm)
    eng = Engine(make_policy(POLICY), ex, classifier,
                 EngineConfig(token_budget=512, prefix_cache=cache,
                              prefix_residual_classify=residual,
                              legacy_scheduling=legacy_sched))
    done = eng.run(generate(_workload()))
    eng.allocator.check_invariants()
    return done, eng, ex


def _summary(done, eng, ex) -> dict:
    s = summarize(done)
    return {
        "ttft_avg": {g: s[g]["ttft_avg"] for g in ("motorcycle", "car",
                                                   "truck", "overall")
                     if s[g] is not None},
        "ttft_p99": round(pctl([r.ttft() for r in done], 99), 5),
        "prefill_tokens": ex.prefill_tokens,
        "cached_prefix_tokens": sum(r.cached_prefix_tokens for r in done),
        "vclass_counts": {v: sum(r.vclass.value == v for r in done)
                          for v in ("motorcycle", "car", "truck")},
        "sim_time_s": round(eng.now, 4),
        "iterations": eng.iterations,
        "prefix": eng.allocator.prefix_stats(),
    }


def measure_sim() -> dict:
    """Deterministic sim measurement (shared with the CI regression
    gate). Asserts cache-on/off output parity before reporting."""
    base, _, smart, _ = stack(MODEL)
    cm = base.cm
    results: dict = {"meta": {
        "model": MODEL, "policy": POLICY, "mix": "MH", "rate": RATE,
        "num_requests": NUM_REQUESTS, "seed": SEED,
        "duplicate_prob": DUPLICATE_PROB,
        "shared_prefix_prob": SHARED_PREFIX_PROB,
        "note": "simulated time on fixed seeds - deterministic baseline",
    }}
    on = _engine_run(smart, cm, cache=True)
    off = _engine_run(smart, cm, cache=False)
    noresid = _engine_run(smart, cm, cache=True, residual=False)
    # equivalence first — real gates, not tautologies:
    # 1. every finished request really covered its whole prompt (a claim
    #    accounting bug would leave prefilled short or claims unbacked)
    for done, eng, ex in (on, off):
        assert all(r.prefilled == r.prompt_tokens for r in done)
        assert all(r.cached_prefix_tokens <= r.prompt_tokens - 1
                   for r in done)
    assert {r.rid for r in on[0]} == {r.rid for r in off[0]}
    # 2. the incremental planner must stay decision-identical to the
    #    brute-force legacy_scheduling oracle *with the cache live*
    legc = _engine_run(smart, cm, cache=True, legacy_sched=True)
    assert [(r.rid, r.ttft(), r.finish_time, r.preemptions)
            for r in on[0]] == \
        [(r.rid, r.ttft(), r.finish_time, r.preemptions)
         for r in legc[0]], \
        "prefix cache broke fast-vs-legacy scheduling decision parity"
    assert on[1].allocator.prefix_stats() == \
        legc[1].allocator.prefix_stats()
    s_on, s_off, s_nr = (_summary(*run) for run in (on, off, noresid))
    results["cache"] = {"on": s_on, "off": s_off}
    results["prefill_token_savings"] = round(
        1.0 - s_on["prefill_tokens"] / s_off["prefill_tokens"], 5)
    results["ttft_improvement"] = {
        "mean": round(1.0 - s_on["ttft_avg"]["overall"]
                      / s_off["ttft_avg"]["overall"], 5),
        "p99": round(1.0 - s_on["ttft_p99"] / s_off["ttft_p99"], 5),
    }
    # rock->sand ablation: same page sharing, ranking ignores the cache
    results["reclass_ablation"] = {
        "no_residual": s_nr,
        "reclassified_requests": sum(
            a.vclass is not b.vclass
            for a, b in zip(sorted(on[0], key=lambda r: r.rid),
                            sorted(noresid[0], key=lambda r: r.rid))),
        "ttft_improvement_vs_no_residual": round(
            1.0 - s_on["ttft_avg"]["overall"]
            / s_nr["ttft_avg"]["overall"], 5),
    }
    return results


def measure_real_parity() -> dict:
    """Real-executor acceptance: bit-identical emitted tokens cache-on vs
    cache-off vs the ``legacy=True`` oracle on a duplicate-heavy mini-mix
    with a forced mid-decode preemption (COW copies + evictions under a
    24-page pool)."""
    from repro.launch.serve import build_stack
    wl = WorkloadConfig(mix="ML", rate=50.0, num_requests=10, seed=7,
                        out_tokens_log_mu=1.8, out_tokens_log_sigma=0.3,
                        text_tokens_log_mu=3.2, text_tokens_log_sigma=0.5,
                        video_frames_min=1, video_frames_max=2,
                        image_patches=32, video_patches_per_frame=16,
                        duplicate_prob=0.6, shared_prefix_prob=0.6,
                        shared_prefix_tokens_min=20,
                        shared_prefix_tokens_max=40)
    emitted, stats = {}, {}
    for key, kind, cache in (("on", "real", True), ("off", "real", False),
                             ("legacy", "real-legacy", True)):
        executor, classifier, engine_cfg, _, _ = build_stack(
            "chatglm3-6b", kind, kv_pages=24)
        engine_cfg.prefix_cache = cache
        eng = Engine(make_policy(POLICY), executor, classifier, engine_cfg)
        pending = generate(wl)
        forced = False
        for _ in range(100000):
            pending = eng.step(pending)
            if not forced and eng.running:
                eng._preempt(next(iter(eng.running)))
                forced = True
            if len(eng.finished) + len(eng.rejected) == 10:
                break
        assert len(eng.finished) == 10
        eng.allocator.check_invariants()
        emitted[key] = {r.rid: eng.executor.emitted.get(r.rid)
                        for r in eng.finished}
        stats[key] = eng.allocator.prefix_stats()
    parity = (emitted["on"] == emitted["off"] == emitted["legacy"]
              and all(toks for toks in emitted["on"].values()))
    return {
        "token_parity": bool(parity),
        "prefix_hits_on": stats["on"]["hits"],
        "cow_copies_on": stats["on"]["cow_copies"],
        "evictions_on": stats["on"]["evictions"],
    }


def measure(fast: bool = False) -> dict:
    results = measure_sim()
    results["real_parity"] = measure_real_parity()
    return results


def main(fast: bool = False):
    rows = []
    results = measure(fast=fast)
    on = results["cache"]["on"]
    off = results["cache"]["off"]
    rp = results["real_parity"]
    sav = results["prefill_token_savings"]
    ti = results["ttft_improvement"]
    print(f"  cache on : prefill tokens {on['prefill_tokens']:>8}  "
          f"TTFT mean {on['ttft_avg']['overall']:.4f}s  "
          f"p99 {on['ttft_p99']:.3f}s  hits {on['prefix']['hits']}  "
          f"cow {on['prefix']['cow_copies']}  "
          f"evictions {on['prefix']['evictions']}")
    print(f"  cache off: prefill tokens {off['prefill_tokens']:>8}  "
          f"TTFT mean {off['ttft_avg']['overall']:.4f}s  "
          f"p99 {off['ttft_p99']:.3f}s")
    print(f"  -> prefill-token savings {sav:.1%}, TTFT mean {ti['mean']:+.1%}"
          f", p99 {ti['p99']:+.1%}")
    ra = results["reclass_ablation"]
    print(f"  rock->sand ablation: {ra['reclassified_requests']} requests "
          f"re-classified; residual ranking worth "
          f"{ra['ttft_improvement_vs_no_residual']:+.1%} mean TTFT on top "
          f"of page sharing alone")
    print(f"  real-executor parity (on/off/legacy): {rp['token_parity']}  "
          f"(hits {rp['prefix_hits_on']}, cow {rp['cow_copies_on']}, "
          f"evictions {rp['evictions_on']})")
    assert rp["token_parity"], \
        "prefix cache changed real-executor emitted tokens"
    assert rp["prefix_hits_on"] > 0, "real parity run exercised no hits"
    assert sav >= 0.30, f"prefill-token savings {sav:.1%} below 30% target"
    assert ti["mean"] > 0, "prefix cache must improve mean TTFT"
    rows.append(csv_row("prefix_cache/prefill_token_savings", sav))
    rows.append(csv_row("prefix_cache/ttft_mean_improvement", ti["mean"]))
    rows.append(csv_row("prefix_cache/ttft_p99_improvement", ti["p99"]))
    rows.append(csv_row("prefix_cache/reclassified",
                        ra["reclassified_requests"], "rock->sand"))
    rows.append(csv_row("prefix_cache/real_token_parity",
                        int(rp["token_parity"]), "bool"))
    if not fast:
        BASELINE_PATH.write_text(json.dumps(results, indent=2) + "\n")
        print(f"  baseline written to {BASELINE_PATH.name}")
    return rows


if __name__ == "__main__":
    import sys
    main(fast="--fast" in sys.argv)
