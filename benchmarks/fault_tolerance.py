"""Chaos benchmark (ISSUE 6): the serving tier under an escalating,
fully-deterministic fault schedule.

Three experiments, all seeded (``--seed`` reproduces a CI failure):

* **Escalation** — one engine under ``FaultRates.scaled(f)`` for rising
  ``f`` (client cancels at every lifecycle stage, per-request deadlines,
  encoder-chunk faults with retry/backoff, transient and permanent
  executor step faults) with load-shedding armed. Exact gates per rung:
  zero allocator invariant violations, zero leaked KV pages, zero leaked
  encoder-cache pin refs, and every request in exactly one terminal
  state. Reported: the goodput/TTFT degradation curve vs fault rate.
* **Failover** — multi-replica stepped co-simulation; the fault plan
  kills one replica mid-run. Exact gates: every in-flight request is
  re-dispatched to a survivor (none lost), no request finishes twice,
  surviving replicas stay invariant-clean with zero leaks. Reported:
  recovery time (kill -> last re-dispatched request terminal).
* **Fault-free identity** — the faults layer installed but empty
  (``FaultPlan()``) must change *nothing*: sim runs keep identical
  per-request timings/states vs ``faults=None``, and a real-executor run
  keeps bit-identical emitted tokens. (Identity to *pre-PR* behaviour is
  additionally pinned by the committed BENCH_encode/prefix/scheduler
  baselines, which the regression gate checks exactly.)

Full mode writes ``BENCH_faults.json`` (the committed baseline checked
by benchmarks/check_regression.py):

    PYTHONPATH=src python -m benchmarks.run --only fault_tolerance [--fast]
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.core.scheduler import make_policy
from repro.serving.engine import Engine, EngineConfig
from repro.serving.executors import SimExecutor, make_cost_model
from repro.serving.faults import FaultPlan, FaultRates
from repro.serving.metrics import goodput, lifecycle_counts, summarize
from repro.serving.router import Router
from repro.serving.workload import WorkloadConfig, generate

from .common import csv_row, resolve_seed, stack

BASELINE_PATH = Path(__file__).resolve().parent.parent / "BENCH_faults.json"

POLICY = "tcm"
DEFAULT_SEED = 7
# per-request / per-iteration base rates the escalation multiplies; at
# 1x roughly a fifth of requests see some fault
BASE_RATES = dict(cancel_prob=0.06, deadline_prob=0.06,
                  encoder_fault_prob=0.08, step_fault_prob=0.003)


def _workload(n: int, seed: int) -> WorkloadConfig:
    # duplicates + shared prefixes so cancels land mid-COW-claim and
    # mid-encode-dedup, not just on private pages
    return WorkloadConfig(mix="MH", rate=2.0, num_requests=n, seed=seed,
                          duplicate_prob=0.3, shared_prefix_prob=0.3)


def _leak_audit(eng: Engine) -> tuple[int, int, int]:
    """(invariant_violations, leaked_pages, leaked_pin_refs) after a run
    in which every request reached a terminal state."""
    violations = 0
    try:
        eng.allocator.check_invariants()
    except AssertionError:
        violations = 1
    pins = (eng.encoder_cache.stats()["pin_refs"]
            if eng.encoder_cache is not None else 0)
    return violations, eng.allocator.used_pages, pins


def run_chaos_rung(factor: float, n: int, seed: int) -> dict:
    """One engine, one escalation rung."""
    _ex, _est, smart, _ = stack()
    cm = make_cost_model("llava-7b")
    rates = FaultRates(**BASE_RATES).scaled(factor)
    plan = FaultPlan(seed=seed, rates=rates)
    # small page pool so pressure, preemption and load-shedding all fire
    eng = Engine(make_policy(POLICY), SimExecutor(cm), smart,
                 EngineConfig(kv_pages=2048, token_budget=512,
                              load_shed=True, shed_after_iters=30),
                 faults=plan)
    reqs = generate(_workload(n, seed))
    eng.run(reqs)
    counts = lifecycle_counts(reqs)
    violations, leaked_pages, leaked_pins = _leak_audit(eng)
    summary = summarize(eng.finished) if eng.finished else None
    return {
        "factor": factor,
        "injected": dict(plan.injected),
        "lifecycle": counts,
        "invariant_violations": violations,
        "leaked_pages": leaked_pages,
        "leaked_pins": leaked_pins,
        "shed": eng.shed_count,
        "goodput": goodput(reqs),
        "ttft_avg": (summary["overall"]["ttft_avg"]
                     if summary and summary["overall"] else None),
    }


def run_failover(n: int, seed: int, replicas: int = 3,
                 kill_time: float = 30.0) -> dict:
    """Multi-replica co-simulation with one replica killed mid-run."""
    _ex, _est, smart, _ = stack()
    cm = make_cost_model("llava-7b")
    plan = FaultPlan(seed=seed, replica_kills={0: kill_time})
    router = Router([SimExecutor(cm) for _ in range(replicas)], smart,
                    EngineConfig(kv_pages=4096, token_budget=512),
                    policy=POLICY, routing="least-loaded", faults=plan)
    reqs = generate(_workload(n, seed + 1))
    router.run_stepped(reqs)
    # terminal partition: every request in exactly one terminal state,
    # every rid in at most one replica's terminal lists
    terminal_rids: list[str] = []
    finished_rids: list[str] = []
    for eng in router.engines:
        for r in eng.finished:
            finished_rids.append(r.rid)
        for r in eng.finished + eng.rejected + eng.aborted:
            terminal_rids.append(r.rid)
    lost = (n - sum(r.is_terminal for r in reqs)) + len(router.lost)
    double_finished = len(finished_rids) - len(set(finished_rids))
    double_terminal = len(terminal_rids) - len(set(terminal_rids))
    violations = leaked_pages = leaked_pins = 0
    for i, eng in enumerate(router.engines):
        if not router.alive[i]:
            continue  # a crashed replica's memory is gone, not leaked
        v, pg, pn = _leak_audit(eng)
        violations += v
        leaked_pages += pg
        leaked_pins += pn
    redis = [r for r in reqs if r.redispatches > 0]
    kill_at = router.kill_events[0]["time"] if router.kill_events else None
    recovery = None
    if kill_at is not None and redis:
        ends = [r.finish_time if r.finish_time is not None else r.aborted_at
                for r in redis if r.is_terminal]
        if ends:
            recovery = max(ends) - kill_at
    return {
        "replicas": replicas,
        "kill_events": router.kill_events,
        "redispatched": router.redispatched,
        "lost": lost,
        "double_finished": double_finished + double_terminal,
        "invariant_violations": violations,
        "leaked_pages": leaked_pages,
        "leaked_pins": leaked_pins,
        "recovery_time": recovery,
        "goodput": goodput(reqs),
    }


def run_fault_free_identity(fast: bool) -> dict:
    """The installed-but-empty faults layer must be a bit-exact no-op."""
    def sim_run(plan):
        _ex, _est, smart, _ = stack()
        cm = make_cost_model("llava-7b")
        eng = Engine(make_policy(POLICY), SimExecutor(cm), smart,
                     EngineConfig(), faults=plan)
        reqs = generate(_workload(150, DEFAULT_SEED))
        eng.run(reqs)
        return {r.rid: (r.state.value, r.finish_time, r.first_token_time,
                        r.decoded, r.preemptions) for r in reqs}

    sim_identical = sim_run(None) == sim_run(FaultPlan())

    # real executor: emitted token streams with the layer installed
    from repro.launch.serve import build_stack
    wl = WorkloadConfig(mix="ML", rate=50.0, num_requests=6, seed=7,
                        out_tokens_log_mu=1.8, out_tokens_log_sigma=0.3,
                        text_tokens_log_mu=3.2, text_tokens_log_sigma=0.5,
                        video_frames_min=1, video_frames_max=2,
                        image_patches=32, video_patches_per_frame=16,
                        duplicate_prob=0.5, shared_prefix_prob=0.5,
                        shared_prefix_tokens_min=20,
                        shared_prefix_tokens_max=40)
    emitted = {}
    for key, plan in (("none", None), ("empty", FaultPlan())):
        executor, classifier, engine_cfg, _, _ = build_stack(
            "chatglm3-6b", "real", kv_pages=64)
        eng = Engine(make_policy(POLICY), executor, classifier, engine_cfg,
                     faults=plan)
        eng.run(generate(wl))
        emitted[key] = {r.rid: executor.emitted.get(r.rid)
                        for r in eng.finished}
    real_identical = (emitted["none"] == emitted["empty"]
                      and len(emitted["none"]) == 6)
    return {"sim_identical": sim_identical,
            "real_identical": real_identical}


def measure(fast: bool = False) -> dict:
    seed = resolve_seed(DEFAULT_SEED)
    factors = [0.0, 2.0] if fast else [0.0, 1.0, 2.0, 4.0]
    n = 120 if fast else 300
    escalation = [run_chaos_rung(f, n, seed) for f in factors]
    failover = run_failover(80 if fast else 240, seed,
                            replicas=2 if fast else 3)
    fault_free = run_fault_free_identity(fast)
    gates = {
        "invariant_violations": (
            sum(r["invariant_violations"] for r in escalation)
            + failover["invariant_violations"]),
        "leaked_pages": (sum(r["leaked_pages"] for r in escalation)
                         + failover["leaked_pages"]),
        "leaked_pins": (sum(r["leaked_pins"] for r in escalation)
                        + failover["leaked_pins"]),
        "in_flight": sum(r["lifecycle"]["in_flight"] for r in escalation),
        "lost": failover["lost"],
        "double_finished": failover["double_finished"],
        "redispatched": failover["redispatched"],
        "fault_free_identical": (fault_free["sim_identical"]
                                 and fault_free["real_identical"]),
    }
    return {"seed": seed, "base_rates": dict(BASE_RATES), "fast": fast,
            "escalation": escalation, "failover": failover,
            "fault_free": fault_free, "gates": gates}


def assert_gates(gates: dict) -> None:
    assert gates["invariant_violations"] == 0, gates
    assert gates["leaked_pages"] == 0, gates
    assert gates["leaked_pins"] == 0, gates
    assert gates["in_flight"] == 0, gates
    assert gates["lost"] == 0, gates
    assert gates["double_finished"] == 0, gates
    assert gates["redispatched"] > 0, \
        "failover never exercised re-dispatch — move the kill earlier"
    assert gates["fault_free_identical"], \
        "installed-but-empty faults layer changed behaviour"


def main(fast: bool = False):
    results = measure(fast=fast)
    rows = []
    print(f"-- escalation (seed {results['seed']}) --")
    print(f"{'factor':>7}{'goodput':>9}{'ttft':>8}{'finished':>9}"
          f"{'cancel':>7}{'failed':>7}{'shed':>6}{'leaks':>6}")
    for r in results["escalation"]:
        lc = r["lifecycle"]
        ttft = r["ttft_avg"] if r["ttft_avg"] is not None else float("nan")
        print(f"{r['factor']:>7.1f}{r['goodput']:>9.3f}{ttft:>8.3f}"
              f"{lc['finished']:>9}{lc['cancelled']:>7}{lc['failed']:>7}"
              f"{r['shed']:>6}{r['leaked_pages'] + r['leaked_pins']:>6}")
        rows.append(csv_row(f"faults.goodput_x{r['factor']:g}",
                            r["goodput"]))
    fo = results["failover"]
    rec = fo["recovery_time"] if fo["recovery_time"] is not None else -1.0
    print(f"-- failover: {fo['replicas']} replicas, kill@"
          f"{fo['kill_events'][0]['time'] if fo['kill_events'] else '-'} "
          f"redispatched {fo['redispatched']} lost {fo['lost']} "
          f"double {fo['double_finished']} recovery {rec:.2f}s")
    ff = results["fault_free"]
    print(f"-- fault-free identity: sim {ff['sim_identical']} "
          f"real {ff['real_identical']}")
    assert_gates(results["gates"])
    print("-- all chaos gates green (zero violations / zero leaks / "
          "none lost / none double-finished / fault-free identical)")
    rows.append(csv_row("faults.failover_recovery_s", rec))
    rows.append(csv_row("faults.redispatched", fo["redispatched"]))
    if not fast:
        BASELINE_PATH.write_text(json.dumps(results, indent=2,
                                            default=str) + "\n")
        print(f"wrote {BASELINE_PATH.name}")
    return rows


if __name__ == "__main__":
    import sys
    main(fast="--fast" in sys.argv)
