"""Roofline report (deliverable g): reads experiments/dryrun/*.json and
prints per (arch x shape x mesh): the three terms, dominant bottleneck,
MODEL_FLOPS / compiled-FLOPs ratio, and a what-would-move-it note."""
import glob
import json
import os

from .common import csv_row

NOTES = {
    ("compute", "train"): "more chips or lower remat factor / MoE dispatch cost",
    ("compute", "prefill"): "near roofline; bigger per-chip batch or kernel fusion",
    ("compute", "decode"): "decode should not be compute-bound; check padding waste",
    ("memory", "decode"): "shrink KV reads: GQA head dedup, window caches, quantized KV",
    ("memory", "train"): "activation sharding (embed_act->model) or larger per-chip arithmetic intensity",
    ("memory", "prefill"): "stream KV writes; fuse attention (flash) to cut activation traffic",
    ("collective", "train"): "overlap FSDP all-gathers with compute; shard params on fewer axes",
    ("collective", "prefill"): "reduce TP all-reduces: 2D sharding or comm/compute overlap",
    ("collective", "decode"): "decode all-reduces dominate at tiny per-step compute; batch bigger or TP smaller",
}


def main(fast: bool = False, outdir: str = "experiments/dryrun"):
    rows = []
    files = sorted(glob.glob(os.path.join(outdir, "*.json")))
    files = [f for f in files if "FAILURES" not in f]
    if not files:
        print("# no dry-run results found; run repro.launch.dryrun_all first")
        return rows
    print("arch,shape,mesh,opts,compute_s,memory_s,collective_s,dominant,"
          "useful_flops_ratio,note")
    for f in files:
        d = json.load(open(f))
        r = d.get("roofline")
        if not r:
            continue
        opts = "+".join(d.get("opts", [])) or "baseline"
        kind = "train" if d["shape"].startswith("train") else (
            "prefill" if "prefill" in d["shape"] else "decode")
        note = NOTES.get((r["dominant"], kind), "")
        print(f"{d['arch']},{d['shape']},{d['mesh']},{opts},"
              f"{r['compute_s']:.3e},{r['memory_s']:.3e},"
              f"{r['collective_s']:.3e},{r['dominant']},"
              f"{r['useful_flops_ratio']:.3f},{note}")
        rows.append(csv_row(
            f"roofline_{d['arch']}_{d['shape']}_{d['mesh']}_{opts}_dominant_s",
            max(r["compute_s"], r["memory_s"], r["collective_s"]),
            r["dominant"]))
    return rows


if __name__ == "__main__":
    main()
