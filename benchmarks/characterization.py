"""Paper Fig. 2: per-modality KV footprint + TTFT, isolated, across models.

Validates the orders-of-magnitude separation insight (text << image << video).
"""
from repro.serving.workload import WorkloadConfig, generate

from .common import PAPER_MODELS, csv_row, pctl, stack


def main(fast: bool = False):
    rows = []
    models = PAPER_MODELS[:3] if fast else PAPER_MODELS
    print("model,modality,kv_tokens_p50,ttft_p50_s,ttft_p90_s")
    for model in models:
        ex, _, _, _ = stack(model)
        reqs = generate(WorkloadConfig(mix="MH", num_requests=400, seed=1))
        by_mod = {}
        for r in reqs:
            rec = ex.isolated_run(r)
            by_mod.setdefault(r.modality.value, []).append(
                (rec.prompt_tokens, rec.ttft))
        for mod, vals in sorted(by_mod.items()):
            kv = [v[0] for v in vals]
            tt = [v[1] for v in vals]
            print(f"{model},{mod},{pctl(kv,50):.0f},{pctl(tt,50):.4f},{pctl(tt,90):.4f}")
            rows.append(csv_row(f"fig2_{model}_{mod}_ttft_p50", pctl(tt, 50),
                                f"kv_p50={pctl(kv,50):.0f}"))
    # insight check: video >> image >> text in both axes
    ex, _, _, _ = stack("llava-7b")
    reqs = generate(WorkloadConfig(mix="MH", num_requests=400, seed=1))
    med = {}
    for r in reqs:
        rec = ex.isolated_run(r)
        med.setdefault(r.modality.value, []).append(rec.ttft)
    assert pctl(med["video"], 50) > pctl(med["image"], 50) > pctl(med["text"], 50)
    return rows


if __name__ == "__main__":
    main()
