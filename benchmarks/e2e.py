"""Paper Fig. 10: TCM vs vLLM(FCFS) vs EDF across multimodal models.
Validates the headline claims: TTFT -54% overall, -78.5% latency-critical."""
from .common import PAPER_MODELS, csv_row, run_policy


def main(fast: bool = False):
    rows = []
    n = 150 if fast else 300
    models = PAPER_MODELS[:3] if fast else PAPER_MODELS
    overall_red, moto_red = [], []
    print("model,policy,M_ttft,C_ttft,T_ttft,O_ttft,O_norm_lat")
    for model in models:
        out = {}
        for pol in ["fcfs", "edf", "tcm"]:
            # heavy-truck regime (paper MH: LLaVA-Video up to 96 frames)
            s, _, _ = run_policy(pol, model=model, n=n,
                                 wl_kwargs={"video_frames_max": 96})
            out[pol] = s
            print(f"{model},{pol},{s['motorcycle']['ttft_avg']:.3f},"
                  f"{s['car']['ttft_avg']:.3f},{s['truck']['ttft_avg']:.3f},"
                  f"{s['overall']['ttft_avg']:.3f},"
                  f"{s['overall']['norm_latency_avg']:.4f}")
        f, t = out["fcfs"], out["tcm"]
        overall_red.append(1 - t["overall"]["ttft_avg"] / f["overall"]["ttft_avg"])
        moto_red.append(1 - t["motorcycle"]["ttft_avg"] / f["motorcycle"]["ttft_avg"])
        rows.append(csv_row(f"fig10_{model}_ttft_reduction_overall",
                            overall_red[-1]))
    avg_o = sum(overall_red) / len(overall_red)
    avg_m = sum(moto_red) / len(moto_red)
    print(f"# headline: overall TTFT reduction avg {avg_o:.1%} (paper 54%); "
          f"latency-critical {avg_m:.1%} (paper 78.5%)")
    rows.append(csv_row("fig10_headline_overall_ttft_reduction", avg_o,
                        "paper=0.54"))
    rows.append(csv_row("fig10_headline_latency_critical_reduction", avg_m,
                        "paper=0.785"))
    return rows


if __name__ == "__main__":
    main()
