"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import numpy as np

from repro.core.classifier import NaiveClassifier, SmartClassifier
from repro.core.estimator import ImpactEstimator
from repro.core.profiler import WorkloadProfiler
from repro.core.scheduler import make_policy
from repro.serving.engine import Engine, EngineConfig
from repro.serving.executors import SimExecutor, make_cost_model
from repro.serving.metrics import summarize
from repro.serving.workload import WorkloadConfig, generate, \
    profiling_workload

PAPER_MODELS = ["llava-500m", "llava-7b", "gemma-4b", "gemma-12b",
                "qwen-3b", "qwen-7b", "pixtral-12b"]

# workload RNG seed shared by the figure benchmarks; ``--seed`` on
# benchmarks/run.py overrides it so any chaos-bench failure printed in a
# CI log is reproducible verbatim
DEFAULT_SEED = 7
SEED_OVERRIDE: int | None = None


def resolve_seed(default: int = DEFAULT_SEED) -> int:
    return SEED_OVERRIDE if SEED_OVERRIDE is not None else default

_STACK_CACHE: dict = {}


def stack(model: str = "llava-7b"):
    """(executor, estimator, smart classifier, profile), cached per model."""
    if model not in _STACK_CACHE:
        cm = make_cost_model(model)
        ex = SimExecutor(cm)
        profile = WorkloadProfiler(ex, model).build(profiling_workload())
        est = ImpactEstimator.train(profile)
        smart = SmartClassifier.train(est, profile)
        _STACK_CACHE[model] = (ex, est, smart, profile)
    return _STACK_CACHE[model]


def run_policy(policy: str, *, model: str = "llava-7b", mix: str = "MH",
               rate: float = 2.0, n: int = 300, seed: int = 7,
               classifier: str = "smart", kv_pages: int = 24576,
               token_budget: int = 512, slo_scale: float = 5.0,
               wl_kwargs: dict | None = None):
    ex, est, smart, _ = stack(model)
    cls = smart if classifier == "smart" else NaiveClassifier(est)
    wl = WorkloadConfig(mix=mix, rate=rate, num_requests=n,
                        seed=resolve_seed(seed),
                        **(wl_kwargs or {}))
    eng = Engine(make_policy(policy), ex, cls,
                 EngineConfig(token_budget=token_budget, kv_pages=kv_pages,
                              slo_scale=slo_scale))
    done = eng.run(generate(wl))
    return summarize(done), done, eng


def csv_row(name: str, value: float, derived: str = "") -> str:
    return f"{name},{value:.6g},{derived}"


def pctl(xs, q):
    xs = [x for x in xs if x is not None]
    return float(np.percentile(xs, q)) if xs else float("nan")
