"""Paper Fig. 7: Impact Estimator prediction error (should be ms-scale even
for second-scale visual TTFTs)."""
from .common import PAPER_MODELS, csv_row, stack


def main(fast: bool = False):
    rows = []
    models = PAPER_MODELS[:2] if fast else PAPER_MODELS
    print("model,modality,kind,mean_abs_err_ms,p90_abs_err_ms")
    for model in models:
        _, est, _, profile = stack(model)
        errs = est.errors(profile)
        for mod, e in sorted(errs.items()):
            kind = est.models[mod].kind
            import numpy as np
            print(f"{model},{mod},{kind},{e.mean()*1e3:.3f},"
                  f"{np.percentile(e,90)*1e3:.3f}")
            rows.append(csv_row(f"fig7_{model}_{mod}_mae_ms", e.mean() * 1e3))
    return rows


if __name__ == "__main__":
    main()
