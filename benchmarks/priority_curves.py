"""Paper Fig. 9: Priority Regulator curves — priority growth and scheduling
score (-log priority) vs waiting time, with the paper's constants."""

from repro.core.regulator import PriorityRegulator
from repro.serving.request import VehicleClass

from .common import csv_row


def main(fast: bool = False):
    rows = []
    reg = PriorityRegulator()
    waits = [0.0, 0.5, 1.0, 2.0, 5.0, 10.0, 30.0, 60.0, 120.0, 300.0]
    print("wait_s,M_priority,C_priority,T_priority,M_score,C_score,T_score")
    for w in waits:
        p = {v: reg.priority(v, w) for v in VehicleClass}
        s = {v: reg.score(v, w) for v in VehicleClass}
        print(f"{w},{p[VehicleClass.MOTORCYCLE]:.4f},{p[VehicleClass.CAR]:.4f},"
              f"{p[VehicleClass.TRUCK]:.6f},{s[VehicleClass.MOTORCYCLE]:.3f},"
              f"{s[VehicleClass.CAR]:.3f},{s[VehicleClass.TRUCK]:.3f}")
    # paper Fig 9a: motorcycles gain priority rapidly; trucks grow very slowly
    assert reg.priority(VehicleClass.MOTORCYCLE, 5.0) > 0.9
    assert reg.priority(VehicleClass.TRUCK, 5.0) < 0.1
    assert reg.priority(VehicleClass.TRUCK, 300.0) > 0.3  # but no starvation
    rows.append(csv_row("fig9_moto_priority_at_5s",
                        reg.priority(VehicleClass.MOTORCYCLE, 5.0)))
    rows.append(csv_row("fig9_truck_priority_at_300s",
                        reg.priority(VehicleClass.TRUCK, 300.0)))
    return rows


if __name__ == "__main__":
    main()
