"""Scheduler host-overhead benchmark (ISSUE 1 tentpole metric).

Measures engine wall-clock and per-iteration host overhead at 3k–10k
request workloads across all five policies, comparing the incremental
scheduling core against the seed's brute-force path
(``EngineConfig.legacy_scheduling=True``: full candidate re-sort +
per-token allocator calls + O(N) membership scans). Every comparison
asserts *decision equivalence* first — identical finish order, TTFT and
finish times on fixed seeds — so the speedup is pure host-overhead
reduction, never a scheduling change.

Full mode writes ``BENCH_scheduler.json`` at the repo root (the tracked
perf baseline); ``--fast`` is a <60 s smoke that checks equivalence and
prints CSV rows without touching the baseline:

    PYTHONPATH=src python -m benchmarks.run --only scheduler_overhead --fast
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from benchmarks.common import csv_row, stack
from repro.core.scheduler import make_policy
from repro.serving.engine import Engine, EngineConfig
from repro.serving.workload import WorkloadConfig, generate

POLICIES = ["fcfs", "edf", "static", "naive-aging", "tcm"]
RATE = 12.0       # req/s: ~6x service capacity -> thousands-deep queues
SEED = 7
BASELINE_PATH = Path(__file__).resolve().parent.parent / \
    "BENCH_scheduler.json"


def _run_engine(policy: str, n: int, *, legacy: bool):
    ex, _, smart, _ = stack("llava-7b")
    eng = Engine(make_policy(policy), ex, smart,
                 EngineConfig(token_budget=512, legacy_scheduling=legacy))
    reqs = generate(WorkloadConfig(mix="MH", rate=RATE, num_requests=n,
                                   seed=SEED))
    t0 = time.perf_counter()
    done = eng.run(reqs)
    wall = time.perf_counter() - t0
    fingerprint = [(r.rid, r.first_token_time, r.finish_time, r.preemptions)
                   for r in done]
    return wall, eng.iterations, fingerprint


def _compare(policy: str, n: int):
    """(incremental_s, legacy_s, iterations); asserts bit-equal decisions."""
    w_inc, it_inc, fp_inc = _run_engine(policy, n, legacy=False)
    w_leg, it_leg, fp_leg = _run_engine(policy, n, legacy=True)
    assert fp_inc == fp_leg, \
        f"{policy}@{n}: incremental scheduling diverged from the seed path"
    assert it_inc == it_leg
    return w_inc, w_leg, it_inc


def main(fast: bool = False):
    rows = []
    results: dict = {"meta": {
        "workload": {"mix": "MH", "rate": RATE, "seed": SEED,
                     "model": "llava-7b", "token_budget": 512},
        "fast": fast,
        "note": "legacy = seed brute-force path; decisions are asserted "
                "bit-identical, so speedup is pure host overhead",
    }, "policies": {}}
    n_sweep = 800 if fast else 3000
    n_head = 2000 if fast else 10000

    for pol in POLICIES:
        w_inc, w_leg, iters = _compare(pol, n_sweep)
        results["policies"][pol] = {
            "num_requests": n_sweep,
            "iterations": iters,
            "legacy_s": round(w_leg, 4),
            "incremental_s": round(w_inc, 4),
            "speedup": round(w_leg / w_inc, 2),
            "legacy_us_per_iter": round(1e6 * w_leg / iters, 2),
            "incremental_us_per_iter": round(1e6 * w_inc / iters, 2),
        }
        rows.append(csv_row(f"sched_overhead/{pol}/n{n_sweep}/legacy_s",
                            w_leg))
        rows.append(csv_row(f"sched_overhead/{pol}/n{n_sweep}/incremental_s",
                            w_inc))
        rows.append(csv_row(f"sched_overhead/{pol}/n{n_sweep}/speedup",
                            w_leg / w_inc, "decisions bit-identical"))
        print(f"  {pol:<12} n={n_sweep}: legacy {w_leg:6.2f}s  "
              f"incremental {w_inc:5.2f}s  ({w_leg / w_inc:4.1f}x, "
              f"{iters} iters)")

    # headline: 10k-request tcm run (the ISSUE acceptance target: >=5x)
    w_inc, w_leg, iters = _compare("tcm", n_head)
    results["headline_tcm"] = {
        "num_requests": n_head,
        "iterations": iters,
        "legacy_s": round(w_leg, 4),
        "incremental_s": round(w_inc, 4),
        "speedup": round(w_leg / w_inc, 2),
    }
    rows.append(csv_row(f"sched_overhead/tcm/n{n_head}/speedup",
                        w_leg / w_inc, "headline; >=5x target"))
    print(f"  headline tcm n={n_head}: legacy {w_leg:.2f}s  "
          f"incremental {w_inc:.2f}s  ({w_leg / w_inc:.1f}x)")
    if not fast:
        assert w_leg / w_inc >= 5.0, \
            f"headline speedup {w_leg / w_inc:.2f}x below the 5x target"
        BASELINE_PATH.write_text(json.dumps(results, indent=2) + "\n")
        print(f"  baseline written to {BASELINE_PATH.name}")
    return rows


if __name__ == "__main__":
    import sys
    main(fast="--fast" in sys.argv)
