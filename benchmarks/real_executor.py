"""Batched paged-KV execution path vs the sequential legacy oracle.

Measures real-JAX decode/prefill wall-clock on CPU for the reduced model at
batch 1/4/8/16: the batched path runs each iteration as one jit-compiled
fused decode step (paged KV, block tables) while ``legacy=True`` replays
the seed's one-eager-``forward``-per-request loop. Token parity between the
two paths is asserted bit-for-bit, and jit recompiles are counted from the
bucket signatures (powers of two over batch/chunk) and asserted bounded.

Full mode writes ``BENCH_executor.json`` (the committed baseline checked by
benchmarks/check_regression.py):

    PYTHONPATH=src python -m benchmarks.run --only real_executor [--fast]
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.cache import BlockAllocator
from repro.configs import get_reduced
from repro.serving.executors import ModelExecutor
from repro.serving.request import Modality, Request, State

BASELINE_PATH = Path(__file__).resolve().parent.parent / \
    "BENCH_executor.json"

ARCH = "chatglm3-6b"
PROMPT_BASE = 40
MAX_LEN = 256


def _mk(rid: str, prompt: int, out: int = 64) -> Request:
    return Request(rid=rid, modality=Modality.TEXT, arrival=0.0,
                   text_tokens=prompt, prompt_tokens=prompt,
                   output_tokens=out)


def _run_one(cfg, batch: int, decode_iters: int, legacy: bool):
    """Prefill `batch` requests, run timed decode iterations.

    Returns (tokens_per_s, prefill_wall_s, emitted_tokens, recompile_keys).
    """
    ex = ModelExecutor(cfg, max_slots=max(16, batch), max_len=MAX_LEN,
                       legacy=legacy)
    alloc = BlockAllocator(num_pages=ex.allocator.num_pages, page_size=16)
    ex.bind_allocator(alloc)
    reqs = [_mk(f"r{i}", PROMPT_BASE + 3 * i) for i in range(batch)]
    for r in reqs:
        alloc.allocate(r.rid, r.prompt_tokens + decode_iters + 8)
        r.state = State.PREFILLING
    t0 = time.perf_counter()
    ex.run_iteration([(r, r.prompt_tokens) for r in reqs], [], [])
    prefill_s = time.perf_counter() - t0
    for r in reqs:
        r.prefilled = r.prompt_tokens
        r.state = State.RUNNING
        r.decoded = 1
    warmup = 3
    for _ in range(warmup):
        ex.run_iteration([], reqs, [])
        for r in reqs:
            r.decoded += 1
    t0 = time.perf_counter()
    for _ in range(decode_iters - warmup):
        ex.run_iteration([], reqs, [])
        for r in reqs:
            r.decoded += 1
    dt = time.perf_counter() - t0
    tps = batch * (decode_iters - warmup) / dt
    emitted = {r.rid: list(ex.emitted[r.rid]) for r in reqs}
    return tps, prefill_s, emitted, sorted(ex.recompile_keys)


def measure(fast: bool = False):
    cfg = get_reduced(ARCH)
    batches = [1, 4, 8] if fast else [1, 4, 8, 16]
    decode_iters = 10 if fast else 28
    curve = {}
    parity = True
    recompiles = {}
    for batch in batches:
        b_tps, b_pre, b_tok, b_keys = _run_one(cfg, batch, decode_iters,
                                               legacy=False)
        l_tps, l_pre, l_tok, _ = _run_one(cfg, batch, decode_iters,
                                          legacy=True)
        parity = parity and (b_tok == l_tok)
        recompiles[str(batch)] = b_keys
        curve[str(batch)] = {
            "batched_tok_s": round(b_tps, 2),
            "legacy_tok_s": round(l_tps, 2),
            "speedup": round(b_tps / l_tps, 3),
            "batched_prefill_s": round(b_pre, 4),
            "legacy_prefill_s": round(l_pre, 4),
            "token_parity": b_tok == l_tok,
        }
    # bucketed shapes bound jit recompiles: one prefill signature and one
    # decode signature per power-of-two batch bucket here
    n_sigs = len({k for keys in recompiles.values() for k in keys})
    return {
        "arch": ARCH,
        "decode_iters": decode_iters,
        "curve": curve,
        "token_parity": parity,
        "recompile_signatures": n_sigs,
        "recompile_keys": recompiles,
    }


def main(fast: bool = False):
    results = measure(fast=fast)
    rows = []
    for b, c in results["curve"].items():
        print(f"  batch {b:>2}: batched {c['batched_tok_s']:8.1f} tok/s  "
              f"legacy {c['legacy_tok_s']:8.1f} tok/s  "
              f"speedup {c['speedup']:.2f}x  parity={c['token_parity']}")
        rows.append(f"real_executor_speedup_b{b},{c['speedup']},tok_s_ratio")
    print(f"  token parity (all batches): {results['token_parity']}")
    print(f"  jit signatures compiled: {results['recompile_signatures']}")
    assert results["token_parity"], \
        "batched path no longer emits bit-identical tokens to legacy"
    # one prefill + one decode signature per batch bucket, small constant
    assert results["recompile_signatures"] <= 2 * len(results["curve"]) + 2, \
        f"unbounded jit recompiles: {results['recompile_keys']}"
    if not fast:
        b8 = results["curve"]["8"]["speedup"]
        assert b8 >= 3.0, f"batch-8 speedup {b8:.2f}x below the 3x target"
        BASELINE_PATH.write_text(json.dumps(results, indent=2) + "\n")
        print(f"  wrote {BASELINE_PATH.name}")
    rows.append(
        f"real_executor_parity,{int(results['token_parity'])},bool")
    return rows


if __name__ == "__main__":
    main()
