"""Batched paged-KV execution path vs the sequential legacy oracle.

Two experiments, both on the real reduced-JAX model (CPU):

* **Batch curve** — decode/prefill wall-clock at batch 1/4/8/16: the
  batched path runs each iteration as one jit-compiled fused decode step
  (paged KV, bucketed block tables) while ``legacy=True`` replays the
  seed's one-``forward``-per-request loop. Emitted-token parity between
  the two paths is asserted exactly, and the jit signatures (powers of
  two over batch/chunk/table-width) are asserted to match the analytic
  bucket model — the O(log) recompile bound, checked key-for-key.
* **Context sweep** — decode/prefill step time at short/medium/long live
  context under a long context cap, ragged (length-bucketed block
  tables) vs the fixed-width geometry (``ragged=False``), at fixed
  batch. The long rung's context comes from the long-context-video
  workload preset (``repro.serving.workload.long_context_video``), so
  the sweep exercises the rocks-near-the-cap regime. Ragged and fixed
  runs must emit identical tokens; the short-context rung must be ≥2×
  faster than fixed width (attention traffic scales with live context,
  not ``max_len``).
* **Capacity sweep** — decode/prefill step time at *fixed live tokens*
  with ``num_pages`` at 1×/4×/8× the demand-sized base. The paged
  stores ride the transformer scan as donated carry, so step time must
  be flat across capacities (<10% spread, full mode), emitted tokens
  bit-exact, and jit keys identical (capacity never enters a
  signature). Timings interleave round-robin across the capacity
  executors to cancel CPU warmup drift.

Full mode writes ``BENCH_executor.json`` (the committed baseline checked
by benchmarks/check_regression.py):

    PYTHONPATH=src python -m benchmarks.run --only real_executor [--fast]
"""
from __future__ import annotations

import json
import statistics
import time
from pathlib import Path

from repro.cache import BlockAllocator
from repro.configs import get_reduced
from repro.serving.executors import ExecutorConfig, ModelExecutor
from repro.serving.request import Modality, Request, State
from repro.serving.workload import generate, long_context_video

BASELINE_PATH = Path(__file__).resolve().parent.parent / \
    "BENCH_executor.json"

ARCH = "chatglm3-6b"
PROMPT_BASE = 40
MAX_LEN = 256
PAGE = 16

SWEEP_BATCH = 8
SWEEP_MAX_LEN = 4096
SWEEP_CHUNK = 256            # engine-style chunked prefill at long context


def _mk(rid: str, prompt: int, out: int = 64) -> Request:
    return Request(rid=rid, modality=Modality.TEXT, arrival=0.0,
                   text_tokens=prompt, prompt_tokens=prompt,
                   output_tokens=out)


def _bucket(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def expected_curve_keys(batch: int, decode_iters: int) -> set:
    """Analytic jit-signature model for one batch-curve run: replays the
    executor's bucketing arithmetic (batch/chunk pow2, block-table width
    = pow2 of the max live page count, capped). The benchmark asserts the
    observed ``recompile_keys`` equal this set — an exact, key-for-key
    version of the O(log) recompile bound."""
    prompts = [PROMPT_BASE + 3 * i for i in range(batch)]
    cap = -(-MAX_LEN // PAGE)      # same ceiling as ModelExecutor.max_pages
    keys = set()
    b = _bucket(batch)
    keys.add(("prefill", b, _bucket(max(prompts)),
              min(_bucket(max(-(-p // PAGE) for p in prompts)), cap)))
    for it in range(decode_iters):
        need = max(-(-(p + it + 1) // PAGE) for p in prompts)
        keys.add(("decode", b, min(_bucket(need), cap)))
    return keys


def _run_one(cfg, batch: int, decode_iters: int, legacy: bool,
             num_pages: int | None = None):
    """Prefill `batch` requests, run timed decode iterations.

    Returns (tokens_per_s, prefill_wall_s, emitted_tokens, executor).
    """
    ex = ModelExecutor(cfg, ExecutorConfig(
        max_slots=max(16, batch), max_len=MAX_LEN, legacy=legacy,
        num_pages=num_pages))
    alloc = BlockAllocator(num_pages=ex.allocator.num_pages, page_size=PAGE)
    ex.bind_allocator(alloc)
    reqs = [_mk(f"r{i}", PROMPT_BASE + 3 * i) for i in range(batch)]
    for r in reqs:
        alloc.allocate(r.rid, r.prompt_tokens + decode_iters + 8)
        r.state = State.PREFILLING
    t0 = time.perf_counter()
    ex.run_iteration([(r, r.prompt_tokens) for r in reqs], [], [])
    prefill_s = time.perf_counter() - t0
    for r in reqs:
        r.prefilled = r.prompt_tokens
        r.state = State.RUNNING
        r.decoded = 1
    warmup = 3
    for _ in range(warmup):
        ex.run_iteration([], reqs, [])
        for r in reqs:
            r.decoded += 1
    steps = []
    for _ in range(decode_iters - warmup):
        t0 = time.perf_counter()
        ex.run_iteration([], reqs, [])
        steps.append(time.perf_counter() - t0)
        for r in reqs:
            r.decoded += 1
    # median step: a growing context can cross a page-bucket boundary
    # mid-run, and that iteration pays a one-off jit compile — steady
    # state (what the curve compares) is the median, not the mean
    tps = batch / statistics.median(steps)
    emitted = {r.rid: list(ex.emitted[r.rid]) for r in reqs}
    return tps, prefill_s, emitted, ex


# ---------------------------------------------------------------------------
# Context sweep
# ---------------------------------------------------------------------------

def sweep_contexts(max_len: int, decode_iters: int) -> tuple[list[int], int]:
    """Sweep rungs: short/medium fixed, long drawn from the
    long-context-video preset's biggest rock prompt (clamped so decode
    stays inside the window)."""
    wl = long_context_video(max_len, num_requests=32, seed=3)
    rock = max(r.prompt_tokens for r in generate(wl)
               if r.modality is Modality.VIDEO)
    # room for the upward prompt stagger + decode window + first-token page
    top = min(max_len - decode_iters - 8 - SWEEP_BATCH, rock)
    rungs = [c for c in (128, 512) if c < top] + [top]
    return rungs, rock


def _sweep_one(cfg, context: int, decode_iters: int, *, ragged: bool,
               legacy: bool = False, max_len: int = SWEEP_MAX_LEN):
    """One sweep cell: chunked prefill to ~``context`` tokens at fixed
    batch, then timed decode steps. Returns
    (decode_step_s, prefill_s, emitted, executor).

    Prompts stagger *upward* from ``context`` so the decode window stays
    inside one page bucket (no mid-measurement jit compile), and a warm
    pass with same-shape throwaway requests (freed before the measured
    set allocates) compiles both signatures first — prefill and decode
    timings are steady-state, not compile-inclusive. The decode step is
    the median across iterations as extra insurance.

    KV capacity is sized to the cell's demand via the ``num_pages``
    override — identical for the ragged and fixed runs, so the cell
    isolates the *geometry* variable. (Step time no longer depends on
    capacity itself — the stores ride the transformer scan as donated
    carry; ``measure_capacity`` gates that directly.)
    """
    pages_per_row = -(-(context + SWEEP_BATCH + decode_iters + 8) // PAGE)
    num_pages = SWEEP_BATCH * pages_per_row + 8
    ex = ModelExecutor(cfg, ExecutorConfig(
        max_slots=2 * SWEEP_BATCH, max_len=max_len, legacy=legacy,
        ragged=ragged, num_pages=num_pages))
    alloc = BlockAllocator(num_pages=num_pages, page_size=PAGE)
    ex.bind_allocator(alloc)

    def _prefill(rs):
        t0 = time.perf_counter()
        while any(r.prefilled < r.prompt_tokens for r in rs):
            work = [(r, min(SWEEP_CHUNK, r.prompt_tokens - r.prefilled))
                    for r in rs if r.prefilled < r.prompt_tokens]
            ex.run_iteration(work, [], [])
            for r, c in work:
                r.prefilled += c
        return time.perf_counter() - t0

    prompts = [context + i for i in range(SWEEP_BATCH)]
    for tag in ("w", "m"):
        reqs = [_mk(f"c{context}{tag}{i}", p) for i, p in enumerate(prompts)]
        for r in reqs:
            alloc.allocate(r.rid, r.prompt_tokens + decode_iters + 8)
            r.state = State.PREFILLING
        prefill_s = _prefill(reqs)
        for r in reqs:
            r.state = State.RUNNING
            r.decoded = 1
        steps = []
        # the warm set only needs to compile the decode signature (the
        # bucket is stable across the window, by construction)
        for _ in range(2 if tag == "w" else decode_iters):
            t0 = time.perf_counter()
            ex.run_iteration([], reqs, [])
            steps.append(time.perf_counter() - t0)
            for r in reqs:
                r.decoded += 1
        if tag == "w":      # throwaway warm set: compile, then free
            for r in reqs:
                r.state = State.FINISHED
                alloc.free(r.rid)
                ex.release_slot(r)
    step_s = statistics.median(steps)
    emitted = {r.rid: list(ex.emitted[r.rid]) for r in reqs}
    return step_s, prefill_s, emitted, ex


def measure_sweep(fast: bool = False) -> dict:
    cfg = get_reduced(ARCH)
    max_len = 1024 if fast else SWEEP_MAX_LEN
    decode_iters = 4 if fast else 12
    contexts, rock = sweep_contexts(max_len, decode_iters)
    if fast:
        contexts = contexts[:1]     # one bucketed prefill+decode cell
    rungs = {}
    bound_ok = True
    parity = True
    for c in contexts:
        r_step, r_pre, r_tok, r_ex = _sweep_one(
            cfg, c, decode_iters, ragged=True, max_len=max_len)
        f_step, f_pre, f_tok, f_ex = _sweep_one(
            cfg, c, decode_iters, ragged=False, max_len=max_len)
        bound_ok = bound_ok and \
            len(r_ex.recompile_keys) <= r_ex.recompile_bound()
        cell = {
            "ragged_step_ms": round(r_step * 1e3, 3),
            "fixed_step_ms": round(f_step * 1e3, 3),
            "decode_speedup": round(f_step / r_step, 3),
            "ragged_prefill_s": round(r_pre, 4),
            "fixed_prefill_s": round(f_pre, 4),
            "prefill_speedup": round(f_pre / r_pre, 3),
            "parity_ragged_fixed": r_tok == f_tok,
        }
        parity = parity and cell["parity_ragged_fixed"]
        if not fast and c == contexts[-1]:
            # long-rung oracle: the sequential dense-slot path at the cap
            _, _, l_tok, _ = _sweep_one(cfg, c, decode_iters, ragged=True,
                                        legacy=True, max_len=max_len)
            cell["parity_vs_legacy"] = r_tok == l_tok
            parity = parity and cell["parity_vs_legacy"]
        rungs[str(c)] = cell
    return {
        "max_len": max_len,
        "batch": SWEEP_BATCH,
        "decode_iters": decode_iters,
        "preset_rock_prompt": rock,
        "rungs": rungs,
        "short_context_decode_speedup": rungs[str(contexts[0])]
        ["decode_speedup"],
        "short_context_prefill_speedup": rungs[str(contexts[0])]
        ["prefill_speedup"],
        "token_parity": parity,
        "recompile_bound_ok": bound_ok,
    }


# ---------------------------------------------------------------------------
# Capacity sweep
# ---------------------------------------------------------------------------

CAP_BATCH = 4
CAP_MULTS_FULL = (1, 4, 8)
CAP_MULTS_FAST = (1, 8)


def _raw_step_args(ex, C: int, maxp: int):
    """Hand-built ``_prefill_jit`` arguments for a C-token step: block
    tables point every page at the trash row, so the scatter pays full
    write traffic without touching live pages."""
    jnp = ex.jnp
    B = CAP_BATCH
    toks = jnp.zeros((B, C), jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32), (B, C))
    bt = jnp.full((B, maxp), ex.allocator.num_pages, jnp.int32)
    lengths = jnp.full((B,), PROMPT_BASE, jnp.int32)
    new_lens = jnp.full((B,), C, jnp.int32)
    return toks, pos, bt, lengths, new_lens


def measure_capacity(fast: bool = False) -> dict:
    """Step time at *fixed live tokens* across a ``num_pages`` capacity
    sweep (1x/4x/8x the demand-sized base). The stores ride the
    transformer scan as donated carry, so prefill- and decode-shaped
    steps must be flat across capacities, with bit-exact emitted tokens
    and identical jit keys (capacity never appears in a jit signature).

    Timing is **interleaved** round-robin across the capacity executors
    — sequential runs see CPU warmup drift that dwarfs any real capacity
    term and would fail the flatness gate spuriously — and the capacity
    order *rotates* each round: the first timed call after a shape
    switch pays a fixed transition cost, which would otherwise land on
    the same capacity every round and read as a spurious spread.
    """
    import jax
    cfg = get_reduced(ARCH)
    mults = CAP_MULTS_FAST if fast else CAP_MULTS_FULL
    decode_iters = 8 if fast else 16
    timing_rounds = 12 if fast else 30
    pages_per_row = -(-(PROMPT_BASE + 3 * CAP_BATCH + decode_iters + 8)
                      // PAGE)
    base_pages = CAP_BATCH * pages_per_row + 8

    # engine-style run per capacity: emitted-token parity + jit keys
    runs = {}
    for m in mults:
        _, _, tok, ex = _run_one(cfg, CAP_BATCH, decode_iters, legacy=False,
                                 num_pages=base_pages * m)
        runs[m] = (tok, ex)
    m0 = mults[0]
    token_parity = all(runs[m][0] == runs[m0][0] for m in mults)
    keys_equal = all(runs[m][1].recompile_keys == runs[m0][1].recompile_keys
                     for m in mults)

    # raw jitted-step timing, interleaved across capacities
    shapes = {"decode": (1, _bucket(pages_per_row)),
              "prefill": (_bucket(PROMPT_BASE), _bucket(pages_per_row))}
    samples = {shape: {m: [] for m in mults} for shape in shapes}
    for shape, (C, maxp) in shapes.items():
        for m in mults:                      # compile + warm each signature
            ex = runs[m][1]
            for _ in range(2):
                out, ex._stores = ex._prefill_jit(
                    ex.params, ex._stores, *_raw_step_args(ex, C, maxp))
                jax.block_until_ready((out, ex._stores))
    for rnd in range(timing_rounds):
        rot = rnd % len(mults)
        order = mults[rot:] + mults[:rot]
        for shape, (C, maxp) in shapes.items():
            for m in order:
                ex = runs[m][1]
                args = _raw_step_args(ex, C, maxp)
                t0 = time.perf_counter()
                out, ex._stores = ex._prefill_jit(ex.params, ex._stores,
                                                  *args)
                jax.block_until_ready((out, ex._stores))
                samples[shape][m].append(time.perf_counter() - t0)

    med = {shape: {m: statistics.median(s) for m, s in per.items()}
           for shape, per in samples.items()}
    spread = {shape: (max(v.values()) - min(v.values())) / min(v.values())
              for shape, v in med.items()}
    return {
        "batch": CAP_BATCH,
        "prompt": PROMPT_BASE,
        "base_pages": base_pages,
        "page_multipliers": list(mults),
        "decode_step_ms": {str(m): round(v * 1e3, 3)
                           for m, v in med["decode"].items()},
        "prefill_step_ms": {str(m): round(v * 1e3, 3)
                            for m, v in med["prefill"].items()},
        "decode_spread": round(spread["decode"], 4),
        "prefill_spread": round(spread["prefill"], 4),
        "token_parity": token_parity,
        "keys_equal": keys_equal,
    }


def measure(fast: bool = False):
    cfg = get_reduced(ARCH)
    batches = [1, 4, 8] if fast else [1, 4, 8, 16]
    decode_iters = 10 if fast else 28
    curve = {}
    parity = True
    recompile_exact = True
    recompiles = {}
    for batch in batches:
        b_tps, b_pre, b_tok, b_ex = _run_one(cfg, batch, decode_iters,
                                             legacy=False)
        l_tps, l_pre, l_tok, _ = _run_one(cfg, batch, decode_iters,
                                          legacy=True)
        parity = parity and (b_tok == l_tok)
        want = expected_curve_keys(batch, decode_iters)
        recompile_exact = recompile_exact and \
            b_ex.recompile_keys == want and \
            len(b_ex.recompile_keys) <= b_ex.recompile_bound()
        recompiles[str(batch)] = sorted(b_ex.recompile_keys)
        curve[str(batch)] = {
            "batched_tok_s": round(b_tps, 2),
            "legacy_tok_s": round(l_tps, 2),
            "speedup": round(b_tps / l_tps, 3),
            "batched_prefill_s": round(b_pre, 4),
            "legacy_prefill_s": round(l_pre, 4),
            "token_parity": b_tok == l_tok,
        }
    n_sigs = len({k for keys in recompiles.values() for k in keys})
    return {
        "arch": ARCH,
        "decode_iters": decode_iters,
        "curve": curve,
        "token_parity": parity,
        "recompile_signatures": n_sigs,
        "recompile_exact": recompile_exact,
        "recompile_keys": recompiles,
        "context_sweep": measure_sweep(fast=fast),
        "capacity_sweep": measure_capacity(fast=fast),
    }


def main(fast: bool = False):
    results = measure(fast=fast)
    rows = []
    for b, c in results["curve"].items():
        print(f"  batch {b:>2}: batched {c['batched_tok_s']:8.1f} tok/s  "
              f"legacy {c['legacy_tok_s']:8.1f} tok/s  "
              f"speedup {c['speedup']:.2f}x  parity={c['token_parity']}")
        rows.append(f"real_executor_speedup_b{b},{c['speedup']},tok_s_ratio")
    print(f"  token parity (all batches): {results['token_parity']}")
    print(f"  jit signatures compiled: {results['recompile_signatures']} "
          f"(exact bucket-model match: {results['recompile_exact']})")
    sweep = results["context_sweep"]
    for ctx, cell in sweep["rungs"].items():
        extra = ""
        if "parity_vs_legacy" in cell:
            extra = f"  legacy_parity={cell['parity_vs_legacy']}"
        print(f"  ctx {ctx:>5}: ragged {cell['ragged_step_ms']:7.2f} ms/step"
              f"  fixed {cell['fixed_step_ms']:7.2f} ms/step  "
              f"decode x{cell['decode_speedup']:.2f}  "
              f"prefill x{cell['prefill_speedup']:.2f}  "
              f"parity={cell['parity_ragged_fixed']}{extra}")
        rows.append(f"real_executor_ctx{ctx}_decode_speedup,"
                    f"{cell['decode_speedup']},step_time_ratio")
    print(f"  sweep parity: {sweep['token_parity']}  recompile bound ok: "
          f"{sweep['recompile_bound_ok']}")
    cap = results["capacity_sweep"]
    for shape in ("decode", "prefill"):
        steps = "  ".join(f"{m}x {v:7.3f} ms"
                          for m, v in cap[f"{shape}_step_ms"].items())
        print(f"  capacity {shape:>7}: {steps}  "
              f"spread {cap[f'{shape}_spread'] * 100:.1f}%")
    print(f"  capacity parity: {cap['token_parity']}  "
          f"jit keys equal: {cap['keys_equal']}")
    rows.append(f"real_executor_capacity_decode_spread,"
                f"{cap['decode_spread']},frac")
    rows.append(f"real_executor_capacity_prefill_spread,"
                f"{cap['prefill_spread']},frac")
    assert cap["token_parity"], \
        "KV capacity changed emitted tokens (must be bit-exact)"
    assert cap["keys_equal"], \
        "KV capacity leaked into jit signatures"
    assert results["token_parity"], \
        "batched path no longer emits token-identical streams to legacy"
    assert results["recompile_exact"], \
        f"jit signatures diverge from the bucket model: " \
        f"{results['recompile_keys']}"
    assert sweep["token_parity"], \
        "ragged geometry changed emitted tokens (vs fixed-width/legacy)"
    assert sweep["recompile_bound_ok"], \
        "recompile keys exceed the O(log) bound under the context sweep"
    if not fast:
        b8 = results["curve"]["8"]["speedup"]
        assert b8 >= 3.0, f"batch-8 speedup {b8:.2f}x below the 3x target"
        for shape in ("decode", "prefill"):
            assert cap[f"{shape}_spread"] < 0.10, \
                (f"{shape} step time varies {cap[f'{shape}_spread']:.1%} "
                 "across the 1x->8x capacity sweep (gate: <10%)")
        short = sweep["short_context_decode_speedup"]
        assert short >= 2.0, \
            f"short-context ragged decode only {short:.2f}x over " \
            "fixed-width (needs >=2x: geometry must scale with live context)"
        BASELINE_PATH.write_text(json.dumps(results, indent=2) + "\n")
        print(f"  wrote {BASELINE_PATH.name}")
    rows.append(
        f"real_executor_parity,{int(results['token_parity'])},bool")
    return rows


if __name__ == "__main__":
    main()
