"""Paper Fig. 15: violation rate, severity and goodput vs SLO scale."""
from repro.serving.metrics import goodput

from .common import csv_row, run_policy


def main(fast: bool = False):
    rows = []
    n = 150 if fast else 300
    print("slo_scale,class,viol_rate,severity,goodput_req_s")
    for scale in [2.5, 5.0, 10.0, 20.0]:
        s, done, _ = run_policy("tcm", n=n, slo_scale=scale)
        gp = goodput(done)
        for g in ["motorcycle", "car", "truck"]:
            print(f"{scale},{g},{s[g]['slo_violation_rate']:.3f},"
                  f"{s[g]['violation_severity_avg']:.2f},{gp:.3f}")
        rows.append(csv_row(f"fig15_slo{scale}_overall_viol",
                            s["overall"]["slo_violation_rate"],
                            f"goodput={gp:.3f}"))
    return rows


if __name__ == "__main__":
    main()
