"""Crash-recovery benchmark (ISSUE 10): kill -> restart -> rejoin cycles,
journal-replay cross-checks, and health-scored auto-drain under
trace-shaped load.

Three experiments, all seeded (``--seed`` reproduces a CI failure):

* **Recovery chaos** — a 4-6 replica fleet at ~10x the failover
  benchmark's request count with every engine journaling: two scheduled
  kills with injected restart delays, an operator drain whose replica
  restarts on the fleet schedule, an auto-drain window so persistently
  DEGRADED replicas drain themselves, and migration chunk faults during
  warm imports. Exact gates audited fleet-wide *including* retired
  (pre-restart) engines: zero allocator invariant violations, zero
  leaked pages/pins, exact terminal-state partition (nothing lost,
  nothing double-finished — a request that finished on a retired engine
  counts exactly once), every scheduled restart rejoined, every
  restarted slot did fresh work post-rejoin, and every journal replay
  agreed with its engine's live accounting bit-exactly (zero mismatches
  across every kill/drain checkpoint and the end-of-run sweep).
* **RTO / goodput recovery** — recovery-time-objective percentiles
  (rejoin minus death per restart event) and the chaos run's goodput as
  a fraction of an event-free run of the same workload.
* **Journal identity** — the journal is pure observation: an event-free
  journal-enabled ``Fleet`` must produce the bit-exact per-request
  timeline and per-replica placement of a journal-less ``Fleet`` AND of
  the plain ``Router``.

Full mode writes ``BENCH_recovery.json`` (the committed baseline checked
by benchmarks/check_regression.py):

    PYTHONPATH=src python -m benchmarks.run --only recovery [--fast]
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.serving.engine import EngineConfig
from repro.serving.executors import SimExecutor, make_cost_model
from repro.serving.faults import FaultPlan, FaultRates
from repro.serving.fleet import Fleet, FleetConfig
from repro.serving.metrics import (goodput, lifecycle_counts, summarize,
                                   summarize_fleet)
from repro.serving.router import Router
from repro.serving.workload import WorkloadConfig, generate

from .common import csv_row, resolve_seed, stack

BASELINE_PATH = Path(__file__).resolve().parent.parent / \
    "BENCH_recovery.json"

POLICY = "tcm"
DEFAULT_SEED = 11
# warm-import transfers run under the same chunk-fault regime the fleet
# benchmark uses, so the retry path is exercised on the rejoin critical
# path too
MIG_RATES = dict(migration_timeout_prob=0.12, migration_corrupt_prob=0.08,
                 permanent_frac=0.05)


def _traced(n: int, seed: int, rate: float) -> WorkloadConfig:
    """PR 8 trace-shaped load: heavy-tailed lengths, diurnal + bursty
    arrivals, zipf-distributed tenants with shared system prompts —
    plus duplicates/shared prefixes so warm imports dedup."""
    return WorkloadConfig(mix="MH", rate=rate, num_requests=n, seed=seed,
                          duplicate_prob=0.3, shared_prefix_prob=0.3,
                          heavy_tail_prob=0.02, heavy_tail_text_cap=8192,
                          heavy_tail_out_cap=1024,
                          diurnal_amplitude=0.5, diurnal_period_s=120.0,
                          burst_prob=0.02, burst_factor=4.0,
                          burst_len_s=5.0,
                          tenants=8, tenant_zipf_a=1.2)


def _recovery_audit(fleet, reqs) -> dict:
    """Conservation audit over every engine that ever served — current
    slots AND retired (pre-restart) engines."""
    engines = list(fleet.engines) + [e for _i, e in fleet.retired]
    violations = leaked_pages = leaked_pins = 0
    for eng in engines:
        try:
            eng.allocator.check_invariants()
        except AssertionError:
            violations += 1
        leaked_pages += eng.allocator.used_pages
        if eng.encoder_cache is not None:
            leaked_pins += eng.encoder_cache.stats()["pin_refs"]
    counts = lifecycle_counts(reqs)
    terminal_rids: list[str] = []
    finished_rids: list[str] = []
    for eng in engines:
        for r in eng.finished:
            finished_rids.append(r.rid)
        for r in eng.finished + eng.rejected + eng.aborted:
            terminal_rids.append(r.rid)
    return {
        "invariant_violations": violations,
        "leaked_pages": leaked_pages,
        "leaked_pins": leaked_pins,
        "in_flight": counts["in_flight"],
        "lost": (len(reqs) - sum(r.is_terminal for r in reqs)
                 + len(fleet.lost) + len(fleet._orphans)),
        "double_finished": (
            (len(finished_rids) - len(set(finished_rids)))
            + (len(terminal_rids) - len(set(terminal_rids)))),
        "lifecycle": counts,
    }


def run_recovery_chaos(n: int, seed: int, replicas: int) -> dict:
    """The headline run: journaled fleet under trace-shaped load with
    two kill->restart cycles, an operator drain->restart, and an
    auto-drain window."""
    _ex, _est, smart, _ = stack()
    cm = make_cost_model("llava-7b")
    reqs = generate(_traced(n, seed, rate=8.0))
    # events off arrival quantiles so they land mid-run at any scale:
    # the kills leave enough tail traffic that the restarted slots do
    # real work after their rejoin gates open
    kill_a = reqs[int(n * 0.35)].arrival
    kill_b = reqs[int(n * 0.50)].arrival
    drain_t = reqs[int(n * 0.45)].arrival
    span = max(r.arrival for r in reqs) - min(r.arrival for r in reqs)
    delay = max(2.0, span * 0.02)
    plan = FaultPlan(seed=seed, rates=FaultRates(**MIG_RATES),
                     replica_kills={replicas - 1: kill_a,
                                    replicas - 2: kill_b},
                     restart_delays={replicas - 1: delay,
                                     replicas - 2: delay * 1.5})
    fleet = Fleet([SimExecutor(cm) for _ in range(replicas)], smart,
                  EngineConfig(kv_pages=4096, token_budget=512,
                               journal=True),
                  policy=POLICY, routing="least-loaded", faults=plan,
                  fleet=FleetConfig(
                      drains={0: drain_t}, restarts={0: delay},
                      restart_warmup_s=2.0, restart_warm_pages=256,
                      auto_drain_window=200))
    done = fleet.run_stepped(reqs)
    audit = _recovery_audit(fleet, reqs)
    summary = summarize(done)
    restarted = {ev["replica"] for ev in fleet.restart_events}
    rejoins = [ev for ev in fleet.health_events
               if ev["state"] == "rejoined"]
    rtos = [ev["rejoin_at"] - ev["died"] for ev in fleet.restart_events]
    # fresh work on restarted engines: a slot's FIRST retired engine is
    # the original; everything after it (and the current engine, if the
    # slot restarted) was created by a restart — their finishes are the
    # post-restart completions. A slot that rejoined after the workload
    # tail legitimately finds nothing; the gate is that the restart
    # cycles collectively did real work
    by_slot: dict[int, list] = {}
    for i, e in fleet.retired:
        by_slot.setdefault(i, []).append(e)
    post_restart = {
        i: sum(len(e.finished)
               for e in by_slot.get(i, [])[1:] + [fleet.engines[i]])
        for i in restarted}
    auto_drains = [d for d in fleet.drain_events if d["cause"] == "auto"]
    return {
        "replicas": replicas,
        "requests": n,
        "kill_times": [kill_a, kill_b],
        "drain_time": drain_t,
        "restart_delay_s": delay,
        "injected": dict(plan.injected),
        "fleet": summarize_fleet(fleet),
        "goodput": goodput(reqs),
        "ttft_avg": (summary["overall"]["ttft_avg"]
                     if summary and summary["overall"] else None),
        "restarted_replicas": sorted(restarted),
        "restarts_fired": len(fleet.restart_events),
        "rejoin_events": len(rejoins),
        "auto_drains": len(auto_drains),
        "rto_p50": float(np.percentile(rtos, 50)) if rtos else None,
        "rto_p95": float(np.percentile(rtos, 95)) if rtos else None,
        "post_restart_finished": post_restart,
        "journal_checks": fleet.journal_checks,
        "journal_mismatches": fleet.verify_journals(),
        **audit,
    }


def run_goodput_recovery(n: int, seed: int, replicas: int,
                         chaos_goodput: float) -> dict:
    """Event-free run of the same trace-shaped workload (journal still
    on): the chaos run's goodput as a fraction of it is the price of
    the outages — restart/rejoin must claw most of it back."""
    _ex, _est, smart, _ = stack()
    cm = make_cost_model("llava-7b")
    reqs = generate(_traced(n, seed, rate=8.0))
    fleet = Fleet([SimExecutor(cm) for _ in range(replicas)], smart,
                  EngineConfig(kv_pages=4096, token_budget=512,
                               journal=True),
                  policy=POLICY, routing="least-loaded",
                  fleet=FleetConfig())
    fleet.run_stepped(reqs)
    base = goodput(reqs)
    return {
        "baseline_goodput": base,
        "chaos_goodput": chaos_goodput,
        "recovery_ratio": chaos_goodput / base if base > 0 else 0.0,
        "journal_mismatches": fleet.verify_journals(),
    }


def run_journal_identity(n: int, seed: int, replicas: int = 4) -> dict:
    """The journal must be pure observation: event-free Fleet with
    journal on == Fleet with journal off == plain Router, bit-exactly
    (per-request timeline AND per-replica placement)."""
    _ex, _est, smart, _ = stack()

    def _run(cls, journal, **kw):
        cm = make_cost_model("llava-7b")
        reqs = generate(_traced(n, seed, rate=4.0))
        router = cls([SimExecutor(cm) for _ in range(replicas)], smart,
                     EngineConfig(kv_pages=4096, token_budget=512,
                                  journal=journal),
                     policy=POLICY, routing="least-loaded", **kw)
        router.run_stepped(reqs)
        snap = {r.rid: (r.state.value, r.finish_time, r.first_token_time,
                        r.decoded, r.preemptions, r.cached_prefix_tokens)
                for r in reqs}
        placement = [sorted(r.rid for r in eng.finished)
                     for eng in router.engines]
        return snap, placement, router

    snap_r, place_r, _ = _run(Router, journal=False)
    snap_off, place_off, _ = _run(Fleet, journal=False,
                                  fleet=FleetConfig())
    snap_on, place_on, fl = _run(Fleet, journal=True, fleet=FleetConfig())
    return {
        "identical": (snap_r == snap_off == snap_on
                      and place_r == place_off == place_on),
        "journal_records": sum(len(e.journal) for e in fl.engines),
        "journal_mismatches": fl.verify_journals(),
    }


def measure(fast: bool = False) -> dict:
    seed = resolve_seed(DEFAULT_SEED)
    # ~10x the failover benchmark's kill count in full mode
    chaos = run_recovery_chaos(n=360 if fast else 2400, seed=seed,
                               replicas=4 if fast else 6)
    recov = run_goodput_recovery(360 if fast else 2400, seed,
                                 4 if fast else 6, chaos["goodput"])
    identity = run_journal_identity(120 if fast else 400, seed)
    gates = {
        "invariant_violations": chaos["invariant_violations"],
        "leaked_pages": chaos["leaked_pages"],
        "leaked_pins": chaos["leaked_pins"],
        "in_flight": chaos["in_flight"],
        "lost": chaos["lost"],
        "double_finished": chaos["double_finished"],
        "journal_checks": chaos["journal_checks"],
        "journal_mismatches": (len(chaos["journal_mismatches"])
                               + len(recov["journal_mismatches"])
                               + len(identity["journal_mismatches"])),
        "restarts_fired": chaos["restarts_fired"],
        "rejoin_events": chaos["rejoin_events"],
        "auto_drains": chaos["auto_drains"],
        "post_restart_finished": sum(
            chaos["post_restart_finished"].values()),
        "rto_positive": bool(chaos["rto_p50"] and chaos["rto_p50"] > 0),
        "recovery_ratio": recov["recovery_ratio"],
        "journal_identity": identity["identical"],
    }
    return {"seed": seed, "fast": fast, "mig_rates": dict(MIG_RATES),
            "chaos": chaos, "recovery": recov, "identity": identity,
            "gates": gates}


def assert_gates(gates: dict) -> None:
    assert gates["invariant_violations"] == 0, gates
    assert gates["leaked_pages"] == 0, gates
    assert gates["leaked_pins"] == 0, gates
    assert gates["in_flight"] == 0, gates
    assert gates["lost"] == 0, gates
    assert gates["double_finished"] == 0, gates
    assert gates["journal_checks"] > 0, \
        "no journal-replay cross-check ever ran"
    assert gates["journal_mismatches"] == 0, \
        "journal replay diverged from live accounting"
    assert gates["restarts_fired"] >= 3, \
        "the scheduled kill/drain restart cycles never all fired"
    assert gates["rejoin_events"] == gates["restarts_fired"], \
        "a fired restart never rejoined"
    assert gates["auto_drains"] >= 1, \
        "the post-kill overload never triggered a health-scored auto-drain"
    assert gates["post_restart_finished"] > 0, \
        "no restarted engine ever did fresh work after its rejoin"
    assert gates["rto_positive"], gates
    assert gates["recovery_ratio"] >= 0.5, \
        "restart/rejoin recovered less than half the event-free goodput"
    assert gates["journal_identity"], \
        "journal-enabled event-free run is no longer bit-exact"


def main(fast: bool = False):
    results = measure(fast=fast)
    rows = []
    ch = results["chaos"]
    print(f"-- recovery chaos (seed {results['seed']}): {ch['replicas']} "
          f"replicas, {ch['requests']} reqs, kills@"
          f"{['%.1f' % t for t in ch['kill_times']]}, drain@"
          f"{ch['drain_time']:.1f}s, restart delay "
          f"{ch['restart_delay_s']:.1f}s --")
    print(f"{'replica':>8}{'state':>10}{'finished':>9}{'journal':>9}"
          f"{'pages':>6}{'pins':>5}")
    for rep in ch["fleet"]["replicas"]:
        print(f"{rep['replica']:>8}{rep['state']:>10}{rep['finished']:>9}"
              f"{rep['journal_records']:>9}{rep['used_pages']:>6}"
              f"{rep['pinned_encoder_entries']:>5}")
    print(f"   restarts: {ch['restarts_fired']} fired, "
          f"{ch['rejoin_events']} rejoined (slots "
          f"{ch['restarted_replicas']}); {ch['auto_drains']} auto-drains; "
          f"RTO p50 {ch['rto_p50']:.2f}s p95 {ch['rto_p95']:.2f}s; "
          f"post-restart finishes {ch['post_restart_finished']}")
    print(f"   journal: {ch['journal_checks']} replay cross-checks, "
          f"{len(ch['journal_mismatches'])} mismatches; injected "
          f"{ch['injected']}")
    print(f"   goodput {ch['goodput']:.3f}  ttft {ch['ttft_avg']:.3f}  "
          f"lost {ch['lost']}  double {ch['double_finished']}")
    rec = results["recovery"]
    print(f"-- goodput recovery: chaos {rec['chaos_goodput']:.3f} / "
          f"event-free {rec['baseline_goodput']:.3f} = "
          f"{rec['recovery_ratio']:.2f}")
    ident = results["identity"]
    print(f"-- journal identity: {ident['identical']} "
          f"({ident['journal_records']} records)")
    assert_gates(results["gates"])
    print("-- all recovery gates green (zero leaks incl. retired engines "
          "/ exact terminal partition / journal replay == live accounting "
          "bit-exact / every restart rejoined & worked / journal-on "
          "bit-exactness)")
    rows.append(csv_row("recovery.rto_p50_s", ch["rto_p50"]))
    rows.append(csv_row("recovery.rto_p95_s", ch["rto_p95"]))
    rows.append(csv_row("recovery.goodput_ratio", rec["recovery_ratio"]))
    rows.append(csv_row("recovery.journal_checks", ch["journal_checks"]))
    rows.append(csv_row("recovery.restarts", len(
        ch["fleet"]["restart_events"])))
    if not fast:
        BASELINE_PATH.write_text(json.dumps(results, indent=2,
                                            default=str) + "\n")
        print(f"wrote {BASELINE_PATH.name}")
    return rows


if __name__ == "__main__":
    import sys
    main(fast="--fast" in sys.argv)
