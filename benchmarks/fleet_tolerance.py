"""Fleet-tier chaos benchmark (ISSUE 9): drains, kills, elastic
repartitioning and page-chain migration under diurnal/bursty traffic.

Four experiments, all seeded (``--seed`` reproduces a CI failure):

* **Fleet chaos** — a 4-8 replica elastic fleet at ~100x the failover
  benchmark's request count (24k requests in full mode, ISSUE 10),
  arrival stream trace-shaped (heavy-tailed lengths, diurnal + bursty
  arrivals, zipf-distributed tenants with shared system prompts),
  with a scheduled kill, scheduled drains, migration chunk faults
  (timeouts + corruptions) and a truck-heavy -> text-only mix shift that
  forces repartitions. Exact gates, audited fleet-wide *including*
  drained and killed replicas (the export path releases everything):
  zero allocator invariant violations, zero leaked KV pages, zero leaked
  encoder-cache pin refs, every request in exactly one terminal state on
  exactly one replica, nothing lost, nothing double-finished.
* **Real-mode migration parity** — a video request is prefilled on one
  real-executor (JAX) replica, its KV page chain migrated (payload
  bytes + checksums) to a second replica mid-flight, and finished
  there. Gate: the migrated run emits bit-identical tokens to an
  unmigrated single-engine oracle, with a non-empty transferred chain.
* **Elastic vs static** — the same mix-shift workload on an elastic
  fleet vs the static truck-isolation partition. Gate: elastic goodput
  and TTFT beat (or match) the static baseline — the repartition pays.
* **No-events identity** — ``Fleet`` with the all-defaults
  ``FleetConfig`` (no drains, no kills, inherited routing) must produce
  the bit-exact per-request timeline and per-replica placement of
  ``Router.run_stepped``.

Full mode writes ``BENCH_fleet.json`` (the committed baseline checked
by benchmarks/check_regression.py):

    PYTHONPATH=src python -m benchmarks.run --only fleet_tolerance [--fast]
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.core.scheduler import make_policy
from repro.serving.engine import Engine, EngineConfig
from repro.serving.executors import SimExecutor, make_cost_model
from repro.serving.faults import FaultPlan, FaultRates
from repro.serving.fleet import Fleet, FleetConfig
from repro.serving.metrics import (goodput, lifecycle_counts, summarize,
                                   summarize_fleet)
from repro.serving.migration import MigrationConfig, migrate
from repro.serving.request import Modality, Request, State
from repro.serving.router import Router
from repro.serving.workload import WorkloadConfig, generate

from .common import csv_row, resolve_seed, stack

BASELINE_PATH = Path(__file__).resolve().parent.parent / "BENCH_fleet.json"

POLICY = "tcm"
DEFAULT_SEED = 7
# migration-domain fault rates for the chaos run: roughly one chunk in
# five faults on its first attempt, so retries genuinely fire; the low
# permanent fraction keeps most faults transient (a permanent chunk
# fault forces the whole transfer to fall back to re-prefill, which the
# tests cover — here the protocol's retry path is the subject)
MIG_RATES = dict(migration_timeout_prob=0.12, migration_corrupt_prob=0.08,
                 permanent_frac=0.05)


def _shaped(mix: str, n: int, seed: int, rate: float,
            trace: bool = False) -> WorkloadConfig:
    """Diurnal + bursty arrivals with duplicates/shared prefixes so
    migrations dedup against target caches, not just fresh imports.
    ``trace=True`` adds the full trace shape (ISSUE 10): heavy-tailed
    lengths and zipf-distributed tenants with shared system prompts."""
    kw = dict(mix=mix, rate=rate, num_requests=n, seed=seed,
              duplicate_prob=0.3, shared_prefix_prob=0.3,
              diurnal_amplitude=0.5, diurnal_period_s=120.0,
              burst_prob=0.02, burst_factor=4.0, burst_len_s=5.0)
    if trace:
        kw.update(heavy_tail_prob=0.02, heavy_tail_text_cap=8192,
                  heavy_tail_out_cap=1024, tenants=8, tenant_zipf_a=1.2)
    return WorkloadConfig(**kw)


def _mix_shift_workload(n: int, seed: int,
                        trace: bool = False) -> list[Request]:
    """Text flood (T0) first half, then a truck flood (LCV): the truck
    share of arriving work explodes mid-run. A static truck-isolation
    partition strands its light replicas while trucks queue on the heavy
    pair; an elastic fleet shrinks the heavy group during the text phase
    and grows it through the truck phase."""
    n1 = n // 2
    p1 = generate(_shaped("T0", n1, seed, rate=12.0, trace=trace))
    p2 = generate(_shaped("LCV", n - n1, seed + 1, rate=3.0, trace=trace))
    off = max(r.arrival for r in p1) + 1.0
    for r in p2:                      # workload rids restart at r00000
        r.rid = "p2" + r.rid
        r._chunks_cache = None
        r.arrival += off
    return sorted(p1 + p2, key=lambda r: r.arrival)


def _fleet_audit(router, reqs) -> dict:
    """Fleet-wide conservation audit — every replica, including drained
    and killed ones (export releases their state, so they must be as
    clean as survivors)."""
    violations = leaked_pages = leaked_pins = 0
    for eng in router.engines:
        try:
            eng.allocator.check_invariants()
        except AssertionError:
            violations += 1
        leaked_pages += eng.allocator.used_pages
        if eng.encoder_cache is not None:
            leaked_pins += eng.encoder_cache.stats()["pin_refs"]
    counts = lifecycle_counts(reqs)
    terminal_rids: list[str] = []
    finished_rids: list[str] = []
    for eng in router.engines:
        for r in eng.finished:
            finished_rids.append(r.rid)
        for r in eng.finished + eng.rejected + eng.aborted:
            terminal_rids.append(r.rid)
    return {
        "invariant_violations": violations,
        "leaked_pages": leaked_pages,
        "leaked_pins": leaked_pins,
        "in_flight": counts["in_flight"],
        "lost": (len(reqs) - sum(r.is_terminal for r in reqs)
                 + len(router.lost)),
        "double_finished": (
            (len(finished_rids) - len(set(finished_rids)))
            + (len(terminal_rids) - len(set(terminal_rids)))),
        "lifecycle": counts,
    }


def run_fleet_chaos(n: int, seed: int, replicas: int) -> dict:
    """The headline run: elastic fleet, mix-shift trace-shaped load
    (heavy tails + diurnal bursts + zipf tenants), one kill, scheduled
    drains, migration faults."""
    _ex, _est, smart, _ = stack()
    cm = make_cost_model("llava-7b")
    reqs = _mix_shift_workload(n, seed, trace=True)
    # schedule events off the arrival stream so they land mid-run at any
    # scale, inside the truck phase (second half) so the drains migrate
    # requests with real multi-page chains; the kill comes later and
    # races the drains' transfers
    drain_a = reqs[int(n * 0.55)].arrival
    kill_t = reqs[int(n * 0.70)].arrival
    drains = {0: drain_a}
    if replicas >= 6:
        drains[1] = reqs[int(n * 0.60)].arrival
    plan = FaultPlan(seed=seed, rates=FaultRates(**MIG_RATES),
                     replica_kills={replicas - 1: kill_t})
    fleet = Fleet([SimExecutor(cm) for _ in range(replicas)], smart,
                  EngineConfig(kv_pages=4096, token_budget=512),
                  policy=POLICY, routing="elastic",
                  truck_replicas=replicas // 2, faults=plan,
                  fleet=FleetConfig(drains=drains,
                                    elastic_window=16, elastic_persist=4,
                                    elastic_dwell_s=1.0))
    fleet.run_stepped(reqs)
    audit = _fleet_audit(fleet, reqs)
    summary = summarize([r for eng in fleet.engines for r in eng.finished])
    return {
        "replicas": replicas,
        "requests": n,
        "drains_scheduled": len(drains),
        "kill_time": kill_t,
        "injected": dict(plan.injected),
        "fleet": summarize_fleet(fleet),
        "goodput": goodput(reqs),
        "ttft_avg": (summary["overall"]["ttft_avg"]
                     if summary and summary["overall"] else None),
        **audit,
    }


def run_real_migration_parity() -> dict:
    """Migrate a real-executor (JAX) request's KV chain between two
    replicas mid-flight; the resumed decode must emit the exact tokens
    of an unmigrated oracle."""
    from repro.launch.serve import build_stack

    def _req():
        # 64 mm units + 16 text tokens: four full shareable pages of
        # video KV, then the private text tail (the chain boundary)
        return Request(rid="mig-parity", modality=Modality.VIDEO,
                       arrival=0.0, text_tokens=16, mm_units=64,
                       prompt_tokens=80, output_tokens=8,
                       mm_hash="parity-vid")

    # oracle: the same request, one engine, never migrated
    ex_o, cls_o, cfg_o, _, _ = build_stack("chatglm3-6b", "real",
                                           kv_pages=64)
    oracle = Engine(make_policy(POLICY), ex_o, cls_o, cfg_o)
    r_o = _req()
    oracle.run([r_o])
    oracle_tokens = ex_o.emitted.get(r_o.rid)

    ex_s, cls_s, cfg_s, _, _ = build_stack("chatglm3-6b", "real",
                                           kv_pages=64)
    ex_d, _, _, _, _ = build_stack("chatglm3-6b", "real", kv_pages=64)
    src = Engine(make_policy(POLICY), ex_s, cls_s, cfg_s)
    dst = Engine(make_policy(POLICY), ex_d, cls_s, cfg_s)
    req = _req()
    pending = [req]
    for _ in range(200):
        pending = src.step(pending)
        if req.state is State.RUNNING:
            break
    prefilled_on_src = req.prefilled
    res = migrate(src, dst, req, src.now, MigrationConfig())
    remaining = [req]
    for _ in range(2000):
        remaining = dst.step(remaining)
        if req.is_terminal:
            break
    migrated_tokens = ex_d.emitted.get(req.rid)
    return {
        "status": res.status,
        "prefilled_on_src": prefilled_on_src,
        "pages_migrated": res.pages_imported,
        "cached_prefix_tokens": req.cached_prefix_tokens,
        "finished": req.state is State.FINISHED,
        "src_leaked_pages": src.allocator.used_pages,
        "dst_leaked_pages": dst.allocator.used_pages,
        "token_parity": (oracle_tokens is not None
                         and oracle_tokens == migrated_tokens),
    }


def run_elastic_vs_static(n: int, seed: int, replicas: int = 4) -> dict:
    """Same mix-shift workload, elastic fleet vs static truck-isolation
    partition: the repartition must pay in goodput/TTFT."""
    _ex, _est, smart, _ = stack()

    def _run(kind):
        cm = make_cost_model("llava-7b")
        reqs = _mix_shift_workload(n, seed)
        kw = dict(policy=POLICY, truck_replicas=replicas // 2)
        if kind == "elastic":
            router = Fleet([SimExecutor(cm) for _ in range(replicas)],
                           smart, EngineConfig(kv_pages=4096,
                                               token_budget=512),
                           routing="elastic",
                           fleet=FleetConfig(elastic_window=16,
                                             elastic_persist=4,
                                             elastic_dwell_s=1.0), **kw)
        else:
            router = Router([SimExecutor(cm) for _ in range(replicas)],
                            smart, EngineConfig(kv_pages=4096,
                                                token_budget=512),
                            routing="truck-isolation", **kw)
        router.run_stepped(reqs)
        done = [r for eng in router.engines for r in eng.finished]
        summary = summarize(done)
        span = max((r.finish_time for r in done if r.finish_time), default=1)
        return {
            "goodput": goodput(reqs),
            "throughput_rps": len(done) / span,
            "ttft_avg": summary["overall"]["ttft_avg"],
            "repartitions": len(getattr(router, "repartition_events", [])),
        }

    elastic = _run("elastic")
    static = _run("static")
    return {
        "elastic": elastic, "static": static,
        "replicas": replicas,
        "beats_static": (elastic["goodput"] >= static["goodput"]
                         and elastic["ttft_avg"] <= static["ttft_avg"]),
    }


def run_no_events_identity(n: int, seed: int, replicas: int = 4) -> dict:
    """Fleet with the all-defaults FleetConfig must be a bit-exact no-op
    over Router: same per-request timeline, same per-replica placement."""
    _ex, _est, smart, _ = stack()

    def _run(cls, **kw):
        cm = make_cost_model("llava-7b")
        reqs = generate(_shaped("MH", n, seed, rate=4.0))
        router = cls([SimExecutor(cm) for _ in range(replicas)], smart,
                     EngineConfig(kv_pages=4096, token_budget=512),
                     policy=POLICY, routing="least-loaded", **kw)
        router.run_stepped(reqs)
        snap = {r.rid: (r.state.value, r.finish_time, r.first_token_time,
                        r.decoded, r.preemptions, r.cached_prefix_tokens)
                for r in reqs}
        placement = [sorted(r.rid for r in eng.finished)
                     for eng in router.engines]
        return snap, placement

    snap_r, place_r = _run(Router)
    snap_f, place_f = _run(Fleet, fleet=FleetConfig())
    return {"identical": snap_r == snap_f and place_r == place_f}


def measure(fast: bool = False) -> dict:
    seed = resolve_seed(DEFAULT_SEED)
    # ~100x the failover benchmark's request count in full mode
    # (ISSUE 10: 10x the previous 2.4k chaos run, trace-shaped)
    chaos = run_fleet_chaos(n=360 if fast else 24_000, seed=seed,
                            replicas=4 if fast else 6)
    parity = run_real_migration_parity()
    elastic = run_elastic_vs_static(240 if fast else 600, seed)
    identity = run_no_events_identity(120 if fast else 400, seed)
    mig = chaos["fleet"]["migrations"]
    gates = {
        "invariant_violations": chaos["invariant_violations"],
        "leaked_pages": (chaos["leaked_pages"]
                         + parity["src_leaked_pages"]
                         + parity["dst_leaked_pages"]),
        "leaked_pins": chaos["leaked_pins"],
        "in_flight": chaos["in_flight"],
        "lost": chaos["lost"],
        "double_finished": chaos["double_finished"],
        "migrations_attempted": mig["attempted"],
        "migrations_succeeded": mig["succeeded"],
        "pages_transferred": mig["pages_transferred"],
        "drains_completed": len(chaos["fleet"]["drain_events"]),
        "drains_scheduled": chaos["drains_scheduled"],
        "repartitions": (len(chaos["fleet"]["repartition_events"])
                         + elastic["elastic"]["repartitions"]),
        "real_migration_parity": (parity["token_parity"]
                                  and parity["finished"]),
        "real_pages_migrated": parity["pages_migrated"],
        "elastic_beats_static": elastic["beats_static"],
        "no_events_identical": identity["identical"],
    }
    return {"seed": seed, "fast": fast, "mig_rates": dict(MIG_RATES),
            "chaos": chaos, "real_migration": parity, "elastic": elastic,
            "identity": identity, "gates": gates}


def assert_gates(gates: dict) -> None:
    assert gates["invariant_violations"] == 0, gates
    assert gates["leaked_pages"] == 0, gates
    assert gates["leaked_pins"] == 0, gates
    assert gates["in_flight"] == 0, gates
    assert gates["lost"] == 0, gates
    assert gates["double_finished"] == 0, gates
    assert gates["migrations_attempted"] > 0, \
        "fleet chaos never exercised migration — move the drains earlier"
    assert gates["migrations_succeeded"] > 0, \
        "no migration ever delivered a chain — protocol or faults broken"
    assert gates["pages_transferred"] > 0, gates
    assert gates["drains_completed"] == gates["drains_scheduled"], \
        "a scheduled drain never completed"
    assert gates["repartitions"] > 0, \
        "the mix shift never triggered an elastic repartition"
    assert gates["real_migration_parity"], \
        "migrated real-executor run no longer emits oracle-identical tokens"
    assert gates["real_pages_migrated"] >= 2, gates
    assert gates["elastic_beats_static"], \
        "elastic repartitioning lost to the static partition"
    assert gates["no_events_identical"], \
        "event-free Fleet is no longer bit-exact with Router"


def main(fast: bool = False):
    results = measure(fast=fast)
    rows = []
    ch = results["chaos"]
    mig = ch["fleet"]["migrations"]
    print(f"-- fleet chaos (seed {results['seed']}): {ch['replicas']} "
          f"replicas, {ch['requests']} reqs, {ch['drains_scheduled']} "
          f"drains, kill@{ch['kill_time']:.1f}s --")
    print(f"{'replica':>8}{'state':>10}{'finished':>9}{'mig_out':>8}"
          f"{'mig_in':>7}{'pages':>6}{'pins':>5}")
    for rep in ch["fleet"]["replicas"]:
        print(f"{rep['replica']:>8}{rep['state']:>10}{rep['finished']:>9}"
              f"{rep['migrations_out']:>8}{rep['migrations_in']:>7}"
              f"{rep['used_pages']:>6}{rep['pinned_encoder_entries']:>5}")
    print(f"   migrations: {mig['attempted']} attempted, "
          f"{mig['succeeded']} succeeded, {mig['fallbacks']} fallbacks, "
          f"{mig['noops']} empty (plain redispatch), {mig['retries']} "
          f"chunk retries; pages {mig['pages_transferred']} transferred "
          f"+ {mig['pages_deduped']} deduped")
    print(f"   drains: {len(ch['fleet']['drain_events'])} completed "
          f"(avg {ch['fleet']['drain_duration_avg']:.2f}s); "
          f"repartitions {len(ch['fleet']['repartition_events'])}; "
          f"injected {ch['injected']}")
    print(f"   goodput {ch['goodput']:.3f}  ttft {ch['ttft_avg']:.3f}  "
          f"lost {ch['lost']}  double {ch['double_finished']}")
    pr = results["real_migration"]
    print(f"-- real-mode migration: {pr['pages_migrated']} pages moved "
          f"({pr['prefilled_on_src']} tokens prefilled on src), cached "
          f"prefix on dst {pr['cached_prefix_tokens']}, token parity "
          f"{pr['token_parity']}")
    el = results["elastic"]
    print(f"-- elastic vs static ({el['replicas']} replicas): goodput "
          f"{el['elastic']['goodput']:.3f} vs {el['static']['goodput']:.3f}"
          f", ttft {el['elastic']['ttft_avg']:.3f} vs "
          f"{el['static']['ttft_avg']:.3f}, repartitions "
          f"{el['elastic']['repartitions']}")
    print(f"-- no-events identity: {results['identity']['identical']}")
    assert_gates(results["gates"])
    print("-- all fleet gates green (zero leaks fleet-wide / exact "
          "terminal partition / oracle token parity / elastic beats "
          "static / event-free bit-exactness)")
    rows.append(csv_row("fleet.chaos_goodput", ch["goodput"]))
    rows.append(csv_row("fleet.migrations_succeeded", mig["succeeded"]))
    rows.append(csv_row("fleet.pages_transferred",
                        mig["pages_transferred"]))
    rows.append(csv_row("fleet.elastic_goodput_gain",
                        el["elastic"]["goodput"] - el["static"]["goodput"]))
    rows.append(csv_row("fleet.elastic_ttft_gain_s",
                        el["static"]["ttft_avg"]
                        - el["elastic"]["ttft_avg"]))
    if not fast:
        BASELINE_PATH.write_text(json.dumps(results, indent=2,
                                            default=str) + "\n")
        print(f"wrote {BASELINE_PATH.name}")
    return rows


if __name__ == "__main__":
    import sys
    main(fast="--fast" in sys.argv)
