"""Paper Figs. 4 + 14: performance as KV-cache capacity shrinks
(100% -> 50% -> 25% -> 12.5%), FCFS (Fig 4) vs TCM (Fig 14)."""
import argparse

from .common import csv_row, run_policy

FULL = 24576


def main(fast: bool = False, policy: str | None = None):
    rows = []
    n = 150 if fast else 300
    policies = [policy] if policy else ["fcfs", "tcm"]
    print("policy,kv_frac,class,ttft_avg,viol_rate,severity,preemptions")
    for pol in policies:
        for frac in [1.0, 0.5, 0.25, 0.125]:
            s, _, _ = run_policy(pol, n=n, kv_pages=int(FULL * frac))
            for g in ["motorcycle", "truck", "overall"]:
                print(f"{pol},{frac},{g},{s[g]['ttft_avg']:.3f},"
                      f"{s[g]['slo_violation_rate']:.3f},"
                      f"{s[g]['violation_severity_avg']:.2f},{s[g]['preemptions']}")
            rows.append(csv_row(f"fig4_{pol}_kv{frac}_overall_viol",
                                s["overall"]["slo_violation_rate"]))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default=None)
    main(policy=ap.parse_args().policy)
