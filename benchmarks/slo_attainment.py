"""SLO-attainment harness (ISSUE 8): offered load swept across the
capacity knee, with and without overload control.

ROADMAP open item 3 asks for trace-shaped production workloads and a
closed-loop benchmark reporting SLO attainment and max sustainable QPS
per policy. This is that harness, plus the overload-control acceptance
gates:

* **Sweep** — a ServeGen-style trace-shaped workload (heavy-tailed
  lengths, diurnal + burst arrivals, a zipf multi-tenant pool with
  distinct modality mixes and shared system prompts) is replayed at
  rising offered rates through two arms: admission ON (SLO-aware
  admission + brownout ladder, serving/admission.py) and admission OFF
  (accept everything). Reported per rung: goodput, SLO attainment,
  rejection mix by class and tenant, brownout transitions. The knee is
  the off-arm's goodput peak. Gates: the ON arm's goodput never
  collapses past the knee (monotone-plateau within tolerance) while the
  OFF arm demonstrably degrades; rejection is modality-aware (rocks
  refused at the highest rate, sand at the lowest); no tenant is fully
  starved at a class where another tenant is served; token buckets
  never go negative; zero leaked pages/pins and an exact terminal-state
  partition at every rung.
* **Chaos composition** — the heaviest overload rung re-run with an
  active ``FaultPlan`` (cancels, deadlines, encoder faults, step
  faults): admission control must compose with the fault machinery —
  same exactness gates, REJECTED co-existing with FAILED/CANCELLED.
* **Identity** — a fault-free, under-capacity run with the admission
  layer *installed* must be bit-identical to one without it (zero
  rejections, identical per-request timings): the controller's
  permissive defaults make installation behaviour-neutral until real
  pressure.

Full mode writes ``BENCH_slo.json`` (committed; checked by
benchmarks/check_regression.py):

    PYTHONPATH=src python -m benchmarks.run --only slo_attainment [--fast]
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.core.scheduler import make_policy
from repro.serving.admission import AdmissionConfig, TenantBudget
from repro.serving.engine import Engine, EngineConfig
from repro.serving.executors import SimExecutor, make_cost_model
from repro.serving.faults import FaultPlan, FaultRates
from repro.serving.metrics import (goodput, lifecycle_counts,
                                   rejection_mix, slo_attainment,
                                   summarize, summarize_tenants)
from repro.serving.workload import WorkloadConfig, generate

from .common import csv_row, resolve_seed, stack

BASELINE_PATH = Path(__file__).resolve().parent.parent / "BENCH_slo.json"

POLICY = "tcm"
DEFAULT_SEED = 7
RATES_FULL = [1.0, 2.0, 4.0, 8.0, 16.0]
RATES_FAST = [2.0, 12.0]
PLATEAU_TOL = 0.7     # ON-arm goodput past the knee stays >= tol * peak
ATTAIN_TARGET = 0.9   # "max sustainable QPS" = highest rate >= this
# same per-request fault rates the chaos benchmark escalates
CHAOS_RATES = dict(cancel_prob=0.06, deadline_prob=0.06,
                   encoder_fault_prob=0.08, step_fault_prob=0.003)


def _workload(rate: float, n: int, seed: int) -> WorkloadConfig:
    """Trace-shaped overload workload: three zipf tenants with distinct
    modality leans and shared system prompts (feeding the prefix cache),
    heavy-tailed lengths, diurnal + burst arrivals, duplicate mm inputs
    (feeding the encoder cache)."""
    return WorkloadConfig(
        mix="MH", rate=rate, num_requests=n, seed=seed,
        duplicate_prob=0.2,
        heavy_tail_prob=0.08, diurnal_amplitude=0.4, diurnal_period_s=60.0,
        burst_prob=0.02, burst_factor=4.0, burst_len_s=5.0,
        tenants=3, tenant_sys_prob=0.75)


def _admission_cfg() -> AdmissionConfig:
    # one tenant carries a finite budget so the token-bucket path is
    # exercised (and its min level gated >= 0); the others are judged
    # purely on feasibility + queue bounds
    return AdmissionConfig(
        tenant_budgets={"tenant2": TenantBudget(rate=3000.0, burst=30000.0)})


def _engine(admission_on: bool, faults=None) -> Engine:
    _ex, _est, smart, _ = stack()
    cm = make_cost_model("llava-7b")
    cfg = EngineConfig(kv_pages=2048, token_budget=512,
                      admission=_admission_cfg() if admission_on else None)
    return Engine(make_policy(POLICY), SimExecutor(cm), smart, cfg,
                  faults=faults)


def _leak_audit(eng: Engine) -> tuple[int, int, int]:
    violations = 0
    try:
        eng.allocator.check_invariants()
    except AssertionError:
        violations = 1
    pins = (eng.encoder_cache.stats()["pin_refs"]
            if eng.encoder_cache is not None else 0)
    return violations, eng.allocator.used_pages, pins


def run_rung(rate: float, n: int, seed: int, admission_on: bool,
             faults=None) -> dict:
    eng = _engine(admission_on, faults=faults)
    reqs = generate(_workload(rate, n, seed))
    eng.run(reqs)
    violations, leaked_pages, leaked_pins = _leak_audit(eng)
    counts = lifecycle_counts(reqs)
    duration = max(eng.now - min(r.arrival for r in reqs), 1e-9)
    summary = summarize(reqs)
    return {
        "rate": rate,
        "admission": admission_on,
        "goodput": goodput(reqs, duration),
        "slo_attainment": slo_attainment(reqs),
        "lifecycle": counts,
        "rejection_mix": rejection_mix(reqs),
        "tenants": summarize_tenants(reqs, duration),
        "overall": summary["overall"],
        "brownout": eng.ladder.describe() if eng.ladder is not None else None,
        "admission_state": (eng.admission.describe()
                            if eng.admission is not None else None),
        "min_bucket_level": (eng.admission.min_bucket_level()
                             if eng.admission is not None else None),
        "invariant_violations": violations,
        "leaked_pages": leaked_pages,
        "leaked_pins": leaked_pins,
        "shed": eng.shed_count,
        "duration": duration,
    }


def _fairness_ok(rungs: list[dict]) -> bool:
    """No tenant fully starved at a class where another tenant is being
    served: whenever one tenant gets >= half its offered requests of a
    class through, every tenant offering a meaningful count (>= 5) at
    that class must get at least one through."""
    for r in rungs:
        for g in ("motorcycle", "car", "truck"):
            served, starved = False, False
            for t in r["tenants"].values():
                offered = (t["served_by_class"][g]
                           + t["rejected_by_class"][g])
                if offered >= 5 and t["served_by_class"][g] == 0:
                    starved = True
                if offered >= 5 and \
                        t["served_by_class"][g] >= 0.5 * offered:
                    served = True
            if served and starved:
                return False
    return True


def _rejection_order_ok(rungs: list[dict]) -> bool:
    """Aggregated over the ON arm's overloaded rungs: trucks refused at
    the highest rate, motorcycles at the lowest, and trucks actually
    refused (the gate is vacuous if nothing was ever rejected)."""
    agg = {g: [0, 0] for g in ("motorcycle", "car", "truck")}
    for r in rungs:
        if r["lifecycle"]["rejected"] == 0:
            continue
        for g, m in r["rejection_mix"].items():
            agg[g][0] += m["offered"]
            agg[g][1] += m["rejected"]
    rates = {g: (rej / off if off else 0.0) for g, (off, rej) in agg.items()}
    return (rates["truck"] > 0.0
            and rates["truck"] >= rates["car"] >= rates["motorcycle"])


def run_identity(seed: int) -> dict:
    """Fault-free, under-capacity: the admission layer installed (with
    its permissive defaults intact — no finite tenant budgets) must be a
    bit-exact no-op, with zero rejections."""
    def one(admission_on: bool):
        _ex, _est, smart, _ = stack()
        cm = make_cost_model("llava-7b")
        cfg = EngineConfig(kv_pages=4096, token_budget=512,
                           admission=AdmissionConfig() if admission_on
                           else None)
        eng = Engine(make_policy(POLICY), SimExecutor(cm), smart, cfg)
        reqs = generate(_workload(1.0, 150, seed))
        eng.run(reqs)
        per_req = {r.rid: (r.state.value, r.finish_time,
                           r.first_token_time, r.decoded, r.preemptions)
                   for r in reqs}
        rejected = sum(1 for r in reqs if r.state.value == "rejected")
        return per_req, rejected

    with_adm, rej = one(True)
    without, _ = one(False)
    return {"identical": with_adm == without, "rejections": rej}


def run_chaos_overload(rate: float, n: int, seed: int) -> dict:
    """Admission control composing with an active FaultPlan at the
    heaviest overload rung: REJECTED must coexist with FAILED/CANCELLED
    under the same exactly-once release machinery."""
    plan = FaultPlan(seed=seed, rates=FaultRates(**CHAOS_RATES))
    r = run_rung(rate, n, seed, admission_on=True, faults=plan)
    r["injected"] = dict(plan.injected)
    return r


def measure(fast: bool = False) -> dict:
    seed = resolve_seed(DEFAULT_SEED)
    rates = RATES_FAST if fast else RATES_FULL
    n = 150 if fast else 400
    on = [run_rung(r, n, seed, admission_on=True) for r in rates]
    off = [run_rung(r, n, seed, admission_on=False) for r in rates]

    # the knee: where the uncontrolled arm's goodput peaks
    off_good = [r["goodput"] for r in off]
    on_good = [r["goodput"] for r in on]
    knee_i = max(range(len(rates)), key=lambda i: off_good[i])
    knee_rate = rates[knee_i]
    past = list(range(knee_i, len(rates)))
    # "monotone-plateau within tolerance": past the knee the controlled
    # arm must hold (a tolerance of) the goodput it delivered AT the
    # knee — overshooting the knee at intermediate rates is fine and
    # must not raise the bar
    plateau_ok = all(on_good[i] >= PLATEAU_TOL * on_good[knee_i]
                     for i in past)
    # the uncontrolled arm demonstrably degrades at the top rate, and
    # overload control beats it there
    off_degrades = off_good[-1] < PLATEAU_TOL * max(off_good) or \
        on_good[-1] > off_good[-1]

    def sustainable(rungs):
        ok = [r["rate"] for r in rungs
              if r["slo_attainment"] >= ATTAIN_TARGET]
        return max(ok) if ok else 0.0

    chaos = run_chaos_overload(rates[-1], n, seed)
    identity = run_identity(seed)

    all_rungs = on + off + [chaos]
    buckets = [r["min_bucket_level"] for r in on + [chaos]
               if r["min_bucket_level"] is not None]
    gates = {
        "plateau_ok": plateau_ok,
        "off_degrades": off_degrades,
        "rejection_order_ok": _rejection_order_ok(on + [chaos]),
        "fairness_ok": _fairness_ok(on),
        "invariant_violations": sum(r["invariant_violations"]
                                    for r in all_rungs),
        "leaked_pages": sum(r["leaked_pages"] for r in all_rungs),
        "leaked_pins": sum(r["leaked_pins"] for r in all_rungs),
        "in_flight": sum(r["lifecycle"]["in_flight"] for r in all_rungs),
        "bucket_min_level": min(buckets) if buckets else float("inf"),
        "chaos_rejected": chaos["lifecycle"]["rejected"],
        "chaos_faulted": (chaos["lifecycle"]["failed"]
                          + chaos["lifecycle"]["cancelled"]),
        "identity_ok": identity["identical"],
        "identity_rejections": identity["rejections"],
    }
    return {
        "seed": seed, "fast": fast, "rates": rates, "n": n,
        "knee_rate": knee_rate,
        "max_sustainable_qps": {"admission_on": sustainable(on),
                                "admission_off": sustainable(off)},
        "sweep_on": on, "sweep_off": off,
        "chaos": chaos, "identity": identity, "gates": gates,
    }


def assert_gates(gates: dict) -> None:
    assert gates["plateau_ok"], \
        "admission-on goodput collapsed past the knee"
    assert gates["off_degrades"], \
        "admission-off never degraded — the sweep does not cross the knee"
    assert gates["rejection_order_ok"], \
        "rejection order is not rocks >= pebbles >= sand"
    assert gates["fairness_ok"], \
        "a tenant was fully starved at a class where another was served"
    assert gates["invariant_violations"] == 0, gates
    assert gates["leaked_pages"] == 0, gates
    assert gates["leaked_pins"] == 0, gates
    assert gates["in_flight"] == 0, gates
    assert gates["bucket_min_level"] >= 0.0, \
        "a tenant token bucket went negative"
    assert gates["chaos_rejected"] > 0 and gates["chaos_faulted"] > 0, \
        "chaos rung did not exercise admission + faults together"
    assert gates["identity_ok"] and gates["identity_rejections"] == 0, \
        "installed admission layer changed an under-capacity run"


def main(fast: bool = False):
    results = measure(fast=fast)
    rows = []
    print(f"-- SLO attainment sweep (seed {results['seed']}, "
          f"knee ~{results['knee_rate']:g} req/s) --")
    print(f"{'rate':>6}{'arm':>5}{'goodput':>9}{'attain':>8}{'fin':>6}"
          f"{'rej':>5}{'shed':>6}{'brownout':>9}")
    for arm, rungs in (("on", results["sweep_on"]),
                       ("off", results["sweep_off"])):
        for r in rungs:
            lc = r["lifecycle"]
            bo = r["brownout"]["transitions"] if r["brownout"] else 0
            print(f"{r['rate']:>6.1f}{arm:>5}{r['goodput']:>9.3f}"
                  f"{r['slo_attainment']:>8.1%}{lc['finished']:>6}"
                  f"{lc['rejected']:>5}{r['shed']:>6}{bo:>9}")
            rows.append(csv_row(
                f"slo.goodput_{arm}_r{r['rate']:g}", r["goodput"]))
    ms = results["max_sustainable_qps"]
    print(f"-- max sustainable QPS (attainment >= {ATTAIN_TARGET:.0%}): "
          f"admission-on {ms['admission_on']:g}, "
          f"admission-off {ms['admission_off']:g}")
    ch = results["chaos"]["lifecycle"]
    print(f"-- chaos+overload: finished {ch['finished']} rejected "
          f"{ch['rejected']} failed {ch['failed']} cancelled "
          f"{ch['cancelled']} in-flight {ch['in_flight']}")
    ident = results["identity"]
    print(f"-- under-capacity identity: {ident['identical']} "
          f"(rejections {ident['rejections']})")
    assert_gates(results["gates"])
    print("-- all overload gates green (plateau / rejection order / "
          "fairness / zero leaks / buckets / identity)")
    rows.append(csv_row("slo.max_qps_on", ms["admission_on"]))
    rows.append(csv_row("slo.max_qps_off", ms["admission_off"]))
    if not fast:
        BASELINE_PATH.write_text(json.dumps(results, indent=2,
                                            default=str) + "\n")
        print(f"wrote {BASELINE_PATH.name}")
    return rows


if __name__ == "__main__":
    import sys
    main(fast="--fast" in sys.argv)
