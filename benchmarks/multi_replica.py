"""Beyond-paper: multi-replica routing (the paper's §4.4 future work).
2-replica cluster at 2x the single-replica rate; round-robin vs
least-loaded vs modality-aware truck isolation."""
from repro.serving.engine import EngineConfig
from repro.serving.executors import SimExecutor
from repro.serving.metrics import summarize
from repro.serving.router import Router
from repro.serving.workload import WorkloadConfig, generate

from .common import csv_row, stack


def main(fast: bool = False):
    rows = []
    n = 200 if fast else 400
    ex0, _, smart, _ = stack("llava-7b")
    print("routing,class,ttft_avg,viol_rate")
    for routing in ["round-robin", "least-loaded", "truck-isolation"]:
        router = Router(
            executors=[SimExecutor(ex0.cm), SimExecutor(ex0.cm)],
            classifier=smart, engine_cfg=EngineConfig(token_budget=512),
            routing=routing)
        reqs = generate(WorkloadConfig(mix="MH", rate=4.0, num_requests=n,
                                       seed=7, video_frames_max=96))
        s = summarize(router.run(reqs))
        for g in ["motorcycle", "car", "truck", "overall"]:
            print(f"{routing},{g},{s[g]['ttft_avg']:.3f},"
                  f"{s[g]['slo_violation_rate']:.3f}")
        rows.append(csv_row(f"router_{routing}_moto_ttft",
                            s["motorcycle"]["ttft_avg"],
                            f"viol={s['motorcycle']['slo_violation_rate']:.2f}"))
    return rows


if __name__ == "__main__":
    main()
