"""Paper Fig. 8 ablation: vLLM-FCFS, Static+Naive classifier, Static+Smart
classifier, Naive Aging, and full TCM (smart + priority regulator)."""
from .common import csv_row, run_policy

VARIANTS = [
    ("vllm-fcfs", "fcfs", "smart"),
    ("static-naive", "static", "naive"),
    ("static-smart", "static", "smart"),
    ("naive-aging", "naive-aging", "smart"),
    ("tcm", "tcm", "smart"),
]


def main(fast: bool = False):
    rows = []
    n = 150 if fast else 300
    print("variant,class,ttft_avg,norm_lat,viol_rate,severity")
    results = {}
    for name, pol, cls in VARIANTS:
        s, _, _ = run_policy(pol, classifier=cls, n=n)
        results[name] = s
        for g in ["motorcycle", "car", "truck", "overall"]:
            print(f"{name},{g},{s[g]['ttft_avg']:.3f},"
                  f"{s[g]['norm_latency_avg']:.4f},"
                  f"{s[g]['slo_violation_rate']:.3f},"
                  f"{s[g]['violation_severity_avg']:.2f}")
        rows.append(csv_row(f"fig8_{name}_overall_norm_lat",
                            s["overall"]["norm_latency_avg"]))
    # paper claims: classification+priority cuts overall norm-latency ~vs fcfs;
    # naive classification penalizes trucks vs smart
    f, sm, nv = results["vllm-fcfs"], results["static-smart"], results["static-naive"]
    assert sm["overall"]["norm_latency_avg"] < f["overall"]["norm_latency_avg"]
    assert sm["truck"]["norm_latency_avg"] <= nv["truck"]["norm_latency_avg"] * 1.05
    return rows


if __name__ == "__main__":
    main()
