"""Decoupled encode pipeline benchmark (ISSUE 2 tentpole metric).

Measures what splitting preprocess+encode out of the prefill path buys:

  * overlap on vs off — encode chunks pipelined with LLM prefill/decode
    (max-composition up to ``CostModel.overlap_efficiency``) against the
    serialized ablation; motorcycles under the MH mix must see lower mean
    TTFT with overlap on (the acceptance gate).
  * encoder cache — a duplicate-heavy mix (``duplicate_prob``) with the
    content-hash cache on vs off: hit rate, TTFT deltas, identical decoded
    work.

Everything here is *simulated* time on fixed seeds, so the numbers are
deterministic — ``BENCH_encode.json`` (written by the full mode) is an
exact baseline that benchmarks/check_regression.py re-derives and compares
with a small float tolerance on every CI run. ``--fast`` runs the same
configuration but skips writing the baseline.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import csv_row, stack
from repro.core.scheduler import make_policy
from repro.serving.engine import Engine, EngineConfig
from repro.serving.executors import SimExecutor
from repro.serving.metrics import summarize, ttft_components
from repro.serving.workload import WorkloadConfig, generate

MODEL = "llava-7b"
POLICY = "tcm"
NUM_REQUESTS = 300
SEED = 7
RATE = 2.5
DUPLICATE_PROB = 0.35
BASELINE_PATH = Path(__file__).resolve().parent.parent / "BENCH_encode.json"


def _engine_run(classifier, cm, wl_cfg, *, overlap=True, cache=True):
    ex = SimExecutor(cm, overlap=overlap)
    eng = Engine(make_policy(POLICY), ex, classifier,
                 EngineConfig(token_budget=512, encoder_cache=cache))
    done = eng.run(generate(wl_cfg))
    return done, eng, ex


def _summary(done, eng, ex) -> dict:
    s = summarize(done)
    comp = ttft_components(done) or {}
    out = {
        "ttft_avg": {g: s[g]["ttft_avg"] for g in ("motorcycle", "car",
                                                   "truck", "overall")
                     if s[g] is not None},
        "sim_time_s": round(eng.now, 4),
        "iterations": eng.iterations,
        "encode_seconds": round(ex.encode_seconds, 4),
        "llm_seconds": round(ex.llm_seconds, 4),
        "overlap_saved_seconds": round(ex.overlap_saved_seconds, 4),
        "ttft_components": {k: round(v, 5) for k, v in comp.items()},
    }
    if eng.encoder_cache is not None:
        out["cache"] = eng.encoder_cache.stats()
    return out


def measure() -> dict:
    """The full (deterministic) measurement dict — shared by main() and
    the CI regression gate."""
    base, _, smart, _ = stack(MODEL)
    cm = base.cm
    wl = WorkloadConfig(mix="MH", rate=RATE, num_requests=NUM_REQUESTS,
                        seed=SEED, video_frames_max=96)
    results: dict = {"meta": {
        "model": MODEL, "policy": POLICY, "mix": "MH", "rate": RATE,
        "num_requests": NUM_REQUESTS, "seed": SEED,
        "duplicate_prob": DUPLICATE_PROB,
        "note": "simulated time on fixed seeds - deterministic baseline",
    }}

    on = _summary(*_engine_run(smart, cm, wl, overlap=True))
    off = _summary(*_engine_run(smart, cm, wl, overlap=False))
    results["overlap"] = {
        "on": on, "off": off,
        "moto_ttft_improvement":
            1.0 - on["ttft_avg"]["motorcycle"] / off["ttft_avg"]["motorcycle"],
        "overall_ttft_improvement":
            1.0 - on["ttft_avg"]["overall"] / off["ttft_avg"]["overall"],
    }

    wl_dup = WorkloadConfig(mix="MH", rate=RATE, num_requests=NUM_REQUESTS,
                            seed=SEED, duplicate_prob=DUPLICATE_PROB)
    hit = _summary(*_engine_run(smart, cm, wl_dup, cache=True))
    miss = _summary(*_engine_run(smart, cm, wl_dup, cache=False))
    results["cache"] = {
        "on": hit, "off": miss,
        "hit_rate": hit["cache"]["hit_rate"],
        "overall_ttft_improvement":
            1.0 - hit["ttft_avg"]["overall"] / miss["ttft_avg"]["overall"],
    }
    return results


def main(fast: bool = False):
    rows = []
    results = measure()
    ov = results["overlap"]
    print(f"  overlap on : moto TTFT {ov['on']['ttft_avg']['motorcycle']:.4f}s"
          f"  overall {ov['on']['ttft_avg']['overall']:.4f}s"
          f"  (saved {ov['on']['overlap_saved_seconds']:.1f}s encode behind"
          f" {ov['on']['llm_seconds']:.1f}s LLM)")
    print(f"  overlap off: moto TTFT {ov['off']['ttft_avg']['motorcycle']:.4f}s"
          f"  overall {ov['off']['ttft_avg']['overall']:.4f}s")
    print(f"  -> motorcycle TTFT improvement {ov['moto_ttft_improvement']:.1%}"
          f", overall {ov['overall_ttft_improvement']:.1%}")
    assert ov["moto_ttft_improvement"] > 0, \
        "encode/prefill overlap must lower motorcycle TTFT on the MH mix"
    rows.append(csv_row("encode_overlap/moto_ttft_on",
                        ov["on"]["ttft_avg"]["motorcycle"]))
    rows.append(csv_row("encode_overlap/moto_ttft_off",
                        ov["off"]["ttft_avg"]["motorcycle"]))
    rows.append(csv_row("encode_overlap/moto_ttft_improvement",
                        ov["moto_ttft_improvement"], "overlap on vs off"))

    ca = results["cache"]
    print(f"  encoder cache (dup={DUPLICATE_PROB}): hit rate "
          f"{ca['hit_rate']:.1%}, overall TTFT "
          f"{ca['on']['ttft_avg']['overall']:.4f}s vs "
          f"{ca['off']['ttft_avg']['overall']:.4f}s without "
          f"({ca['overall_ttft_improvement']:+.1%})")
    rows.append(csv_row("encode_overlap/cache_hit_rate", ca["hit_rate"]))
    rows.append(csv_row("encode_overlap/cache_overall_ttft_improvement",
                        ca["overall_ttft_improvement"]))

    if not fast:
        BASELINE_PATH.write_text(json.dumps(results, indent=2) + "\n")
        print(f"  baseline written to {BASELINE_PATH.name}")
    return rows


if __name__ == "__main__":
    import sys
    main(fast="--fast" in sys.argv)
