"""Paper Fig. 6: TTFT decomposition (preprocess / encode / prefill) per
modality and model — plus the live-engine decomposition: with the encode
stage decoupled (ISSUE 2), TTFT splits into preprocess, encode-wait,
encode, prefill-queue-wait, and prefill, measured on actual engine runs
rather than isolated requests."""
from repro.core.scheduler import make_policy
from repro.serving.engine import Engine, EngineConfig
from repro.serving.metrics import ttft_components
from repro.serving.workload import WorkloadConfig, generate

from .common import PAPER_MODELS, csv_row, stack

COMPONENTS = ("preprocess", "encode_wait", "encode", "queue_wait", "prefill")


def main(fast: bool = False):
    rows = []
    models = PAPER_MODELS[:3] if fast else PAPER_MODELS
    print("model,modality,preprocess_s,encode_s,prefill_s")
    for model in models:
        ex, _, _, _ = stack(model)
        reqs = generate(WorkloadConfig(mix="MH", num_requests=300, seed=2))
        agg = {}
        for r in reqs:
            rec = ex.isolated_run(r)
            a = agg.setdefault(r.modality.value, [0.0, 0.0, 0.0, 0])
            a[0] += rec.preprocess_time
            a[1] += rec.encode_time
            a[2] += rec.prefill_time
            a[3] += 1
        for mod, (p, e, f, n) in sorted(agg.items()):
            print(f"{model},{mod},{p/n:.4f},{e/n:.4f},{f/n:.4f}")
            rows.append(csv_row(f"fig6_{model}_{mod}_prefill_share",
                                (f / n) / max((p + e + f) / n, 1e-12)))

    # live-engine decomposition: where a request's TTFT actually goes when
    # it contends with the rest of the MH mix (encode-wait vs encode vs
    # prefill-queue-wait vs prefill)
    ex, _, smart, _ = stack("llava-7b")
    eng = Engine(make_policy("tcm"), ex, smart,
                 EngineConfig(token_budget=512))
    n = 150 if fast else 400
    done = eng.run(generate(WorkloadConfig(mix="MH", rate=2.0,
                                           num_requests=n, seed=2)))
    print("\nengine TTFT decomposition (MH @ 2 rps, tcm):")
    print("modality," + ",".join(COMPONENTS))
    by_mod = {}
    for r in done:
        by_mod.setdefault(r.modality.value, []).append(r)
    for mod in sorted(by_mod):
        comp = ttft_components(by_mod[mod])
        if comp is None:
            continue
        print(f"{mod}," + ",".join(f"{comp[k]:.4f}" for k in COMPONENTS))
        total = sum(comp.values())
        if total > 0:
            rows.append(csv_row(
                f"engine_ttft_{mod}_encode_wait_share",
                (comp["encode_wait"] + comp["encode"]) / total,
                "decoupled encode stage"))
            rows.append(csv_row(f"engine_ttft_{mod}_queue_wait_share",
                                comp["queue_wait"] / total))
    return rows


if __name__ == "__main__":
    main()
