"""Paper Fig. 6: TTFT decomposition (preprocess / encode / prefill) per
modality and model."""
from repro.serving.workload import WorkloadConfig, generate

from .common import PAPER_MODELS, csv_row, stack


def main(fast: bool = False):
    rows = []
    models = PAPER_MODELS[:3] if fast else PAPER_MODELS
    print("model,modality,preprocess_s,encode_s,prefill_s")
    for model in models:
        ex, _, _, _ = stack(model)
        reqs = generate(WorkloadConfig(mix="MH", num_requests=300, seed=2))
        agg = {}
        for r in reqs:
            rec = ex.isolated_run(r)
            a = agg.setdefault(r.modality.value, [0.0, 0.0, 0.0, 0])
            a[0] += rec.preprocess_time
            a[1] += rec.encode_time
            a[2] += rec.prefill_time
            a[3] += 1
        for mod, (p, e, f, n) in sorted(agg.items()):
            print(f"{model},{mod},{p/n:.4f},{e/n:.4f},{f/n:.4f}")
            rows.append(csv_row(f"fig6_{model}_{mod}_prefill_share",
                                (f / n) / max((p + e + f) / n, 1e-12)))
    return rows


if __name__ == "__main__":
    main()
