"""Benchmark driver: one function per paper table/figure.
Prints ``name,value,derived`` CSV rows for every benchmark.

    python -m benchmarks.run [--fast] [--only SUBSTR] [--list]

``--only`` runs the benchmarks whose name contains SUBSTR; a substring
matching nothing is an error (exit 2) listing the known names — a typo
must not silently run nothing and report success.
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--seed", type=int, default=None,
                    help="override every benchmark's workload RNG seed "
                         "(reproduce a chaos-bench failure from its log)")
    ap.add_argument("--list", action="store_true",
                    help="print the known benchmark names and exit")
    args = ap.parse_args()

    from . import (ablation, assigned_archs, characterization, common,
                   decode_priority, e2e,
                   encode_overlap, estimator_accuracy, fault_tolerance,
                   fleet_tolerance, load_scaling,
                   memory_pressure, multi_replica, preemptions, prefix_cache,
                   priority_curves, real_executor, recovery, roofline,
                   scheduler_overhead, slo_attainment, slo_scales,
                   ttft_breakdown, workload_mix, workloads_tcm)
    common.SEED_OVERRIDE = args.seed
    benches = [
        ("scheduler_overhead", scheduler_overhead),
        ("encode_overlap", encode_overlap),
        ("real_executor", real_executor),
        ("prefix_cache", prefix_cache),
        ("fault_tolerance", fault_tolerance),
        ("fleet_tolerance", fleet_tolerance),
        ("recovery", recovery),
        ("slo_attainment", slo_attainment),
        ("fig2_characterization", characterization),
        ("fig3_workload_mix", workload_mix),
        ("fig4_14_memory_pressure", memory_pressure),
        ("fig6_ttft_breakdown", ttft_breakdown),
        ("fig7_estimator_accuracy", estimator_accuracy),
        ("fig8_ablation", ablation),
        ("fig9_priority_curves", priority_curves),
        ("fig10_e2e", e2e),
        ("fig11_preemptions", preemptions),
        ("fig12_load_scaling", load_scaling),
        ("fig13_workloads_tcm", workloads_tcm),
        ("fig15_slo_scales", slo_scales),
        ("beyond_decode_priority", decode_priority),
        ("beyond_multi_replica", multi_replica),
        ("assigned_archs_tcm", assigned_archs),
        ("roofline", roofline),
    ]
    if args.list:
        for name, _mod in benches:
            print(name)
        return
    selected = [(name, mod) for name, mod in benches
                if not args.only or args.only in name]
    if not selected:
        print(f"error: --only {args.only!r} matched no benchmark",
              file=sys.stderr)
        print("known benchmarks:\n  " +
              "\n  ".join(name for name, _m in benches), file=sys.stderr)
        sys.exit(2)
    all_rows = []
    for name, mod in selected:
        t0 = time.time()
        print(f"\n===== {name} =====")
        print(f"# rng seed: {common.resolve_seed()}"
              + (" (--seed override)" if args.seed is not None
                 else " (default)"))
        rows = mod.main(fast=args.fast) or []
        all_rows.extend(rows)
        print(f"# {name} done in {time.time()-t0:.1f}s")
    print("\n===== CSV SUMMARY (name,value,derived) =====")
    for row in all_rows:
        print(row)


if __name__ == "__main__":
    main()
