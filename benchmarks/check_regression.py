"""CI perf-regression gate (non-blocking job in .github/workflows/ci.yml).

Compares a fresh smoke run against the committed baselines and exits
non-zero on regression, so perf drift is visible on every PR without
blocking it:

  * ``BENCH_encode.json`` — *simulated* time on fixed seeds, fully
    deterministic: the fresh run must match the baseline within a small
    float tolerance (a mismatch means engine/cost-model behaviour changed
    without regenerating the baseline).
  * ``BENCH_scheduler.json`` — host wall-clock speedups (incremental vs
    seed brute-force scheduling). CI runners are slow and noisy and the
    smoke uses smaller workloads than the committed full run, so the gate
    is generous: the fresh speedup only has to clear a floor derived from
    the committed headline, never match it. Decision equivalence between
    the fast and legacy paths is still asserted exactly (by ``_compare``).
  * ``BENCH_executor.json`` — real-JAX batched-vs-legacy executor.
    Token parity (batch curve, the ragged context sweep, AND the KV
    capacity sweep), the recompile-key check (observed jit signatures
    == the analytic bucket model, within the O(log) ``recompile_bound``)
    and capacity-independence of the jit keys are exact gates (they are
    deterministic); the batch-8 decode speedup, the short-context
    ragged-vs-fixed speedup, and the capacity-sweep step-time spread
    are wall-clock, so they only have to clear generous floors/ceilings
    of the committed headlines.
  * ``BENCH_prefix.json`` — KV prefix cache. Real-executor token parity
    (cache on/off/legacy) and the sim hit/COW/reclassification counts
    are exact gates; the prefill-token savings and TTFT improvements are
    deterministic sim floats checked within the small tolerance.
  * ``BENCH_faults.json`` — chaos harness. All gates are exact and
    wall-clock-free: zero allocator invariant violations, zero leaked
    pages/encoder-cache pin refs, failover loses/double-finishes
    nothing, and the installed-but-empty faults layer is a bit-exact
    no-op (sim timings and real emitted tokens).
  * ``BENCH_fleet.json`` — fleet tier. All gates exact and
    wall-clock-free from a fresh fast run: zero invariant violations /
    leaked pages / pins audited fleet-wide *including* drained and
    killed replicas, exact terminal-state partition (nothing lost or
    double-finished) under drains + a kill + migration chunk faults,
    every scheduled drain completed, the mix shift repartitioned the
    elastic group, real-executor migration emits oracle-identical
    tokens over a non-empty transferred chain, elastic beats the
    static partition, and the event-free ``Fleet`` is a bit-exact
    no-op over ``Router``.
  * ``BENCH_recovery.json`` — crash recovery. All gates exact and
    wall-clock-free from a fresh fast run: zero invariant violations /
    leaked pages / pins audited over every engine that ever served
    (retired pre-restart engines included), exact terminal-state
    partition across kill->restart->rejoin cycles and auto-drains,
    every fired restart rejoined and the restart cycles did fresh
    work, zero journal-replay mismatches (the lifecycle journal's
    replayed accounting must equal the live allocator/engine state
    bit-exactly), a goodput-recovery floor, and the journal-enabled
    event-free run a bit-exact no-op over ``Router``.
  * ``BENCH_slo.json`` — overload control. Exact, wall-clock-free
    gates from a fresh fast sweep: zero leaks / exact terminal-state
    partition under sustained overload (with and without chaos), the
    modality-aware rejection order (rocks before pebbles before sand),
    tenant token buckets never negative, tenant fairness, the
    admission-on goodput plateau past the knee, and the installed
    admission layer a bit-exact no-op under capacity. The sweep runs
    simulated time, so "generous on wall-clock" is moot — every gate
    is deterministic.

    PYTHONPATH=src python -m benchmarks.check_regression [--skip-wallclock]
"""
from __future__ import annotations

import json
import math
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# deterministic sim metrics: allow tiny cross-platform float drift
SIM_REL_TOL = 0.02
# host wall-clock: fresh fast-smoke speedup must clear this fraction of the
# committed (larger-workload) speedup, and at least break even. The smoke
# runs a much smaller workload than the committed n=10000 headline (where
# the incremental path's advantage is far larger) on a noisy shared
# runner, hence the very generous fraction — the check is really "the
# incremental scheduler is still clearly faster than brute force".
WALLCLOCK_FRACTION = 0.05
WALLCLOCK_FLOOR = 1.0
WALLCLOCK_N = 2000


def _close(a: float, b: float, rel: float = SIM_REL_TOL) -> bool:
    return math.isclose(a, b, rel_tol=rel, abs_tol=1e-9)


def check_encode_baseline(failures: list[str]) -> None:
    path = ROOT / "BENCH_encode.json"
    if not path.exists():
        failures.append("BENCH_encode.json missing - run "
                        "`python -m benchmarks.run --only encode_overlap`")
        return
    baseline = json.loads(path.read_text())
    from benchmarks.encode_overlap import measure
    fresh = measure()
    checks = [
        ("overlap.moto_ttft_on",
         baseline["overlap"]["on"]["ttft_avg"]["motorcycle"],
         fresh["overlap"]["on"]["ttft_avg"]["motorcycle"]),
        ("overlap.moto_ttft_off",
         baseline["overlap"]["off"]["ttft_avg"]["motorcycle"],
         fresh["overlap"]["off"]["ttft_avg"]["motorcycle"]),
        ("overlap.overall_ttft_on",
         baseline["overlap"]["on"]["ttft_avg"]["overall"],
         fresh["overlap"]["on"]["ttft_avg"]["overall"]),
        ("cache.hit_rate",
         baseline["cache"]["hit_rate"], fresh["cache"]["hit_rate"]),
        ("cache.overall_ttft_on",
         baseline["cache"]["on"]["ttft_avg"]["overall"],
         fresh["cache"]["on"]["ttft_avg"]["overall"]),
    ]
    for name, want, got in checks:
        status = "ok" if _close(want, got) else "REGRESSION"
        print(f"  encode/{name}: baseline {want:.5f}  fresh {got:.5f}  "
              f"[{status}]")
        if status != "ok":
            failures.append(f"encode/{name}: {got:.5f} vs baseline "
                            f"{want:.5f} (tol {SIM_REL_TOL:.0%})")
    if fresh["overlap"]["moto_ttft_improvement"] <= 0:
        failures.append("encode/overlap no longer improves motorcycle TTFT")


def check_scheduler_baseline(failures: list[str]) -> None:
    path = ROOT / "BENCH_scheduler.json"
    if not path.exists():
        failures.append("BENCH_scheduler.json missing - run "
                        "`python -m benchmarks.run --only scheduler_overhead`")
        return
    baseline = json.loads(path.read_text())
    committed = baseline["headline_tcm"]["speedup"]
    floor = max(WALLCLOCK_FLOOR, WALLCLOCK_FRACTION * committed)
    # small fast-smoke workload; _compare also asserts the fast path's
    # decisions stay bit-identical to legacy_scheduling
    from benchmarks.scheduler_overhead import _compare
    w_inc, w_leg, iters = _compare("tcm", WALLCLOCK_N)
    fresh = w_leg / w_inc
    status = "ok" if fresh >= floor else "REGRESSION"
    print(f"  scheduler/tcm_speedup: committed {committed:.1f}x "
          f"(n={baseline['headline_tcm']['num_requests']}), fresh fast-smoke "
          f"{fresh:.1f}x over {iters} iters, floor {floor:.1f}x  [{status}]")
    if status != "ok":
        failures.append(f"scheduler/tcm_speedup {fresh:.2f}x below floor "
                        f"{floor:.2f}x (committed {committed:.2f}x)")


def check_executor_baseline(failures: list[str],
                            skip_wallclock: bool) -> None:
    path = ROOT / "BENCH_executor.json"
    if not path.exists():
        failures.append("BENCH_executor.json missing - run "
                        "`python -m benchmarks.run --only real_executor`")
        return
    baseline = json.loads(path.read_text())
    from benchmarks.real_executor import measure
    fresh = measure(fast=True)
    # exact gates: both are deterministic on any platform
    parity = fresh["token_parity"]
    print(f"  executor/token_parity: {parity}  "
          f"[{'ok' if parity else 'REGRESSION'}]")
    if not parity:
        failures.append("executor/token_parity: batched path no longer "
                        "emits token-identical streams to legacy")
    # observed jit signatures must equal the analytic bucket model
    # (exact, derived in-benchmark so workload edits cannot
    # desynchronize the gate) — this is the O(log) recompile bound
    sig_ok = fresh["recompile_exact"]
    print(f"  executor/recompile_keys: exact bucket-model match {sig_ok}  "
          f"[{'ok' if sig_ok else 'REGRESSION'}]")
    if not sig_ok:
        failures.append("executor/recompile_keys diverge from the bucket "
                        f"model: {fresh['recompile_keys']}")
    sweep = fresh["context_sweep"]
    sweep_ok = sweep["token_parity"] and sweep["recompile_bound_ok"]
    print(f"  executor/sweep: parity {sweep['token_parity']}  "
          f"recompile_bound {sweep['recompile_bound_ok']}  "
          f"[{'ok' if sweep_ok else 'REGRESSION'}]")
    if not sweep["token_parity"]:
        failures.append("executor/sweep: ragged geometry changed emitted "
                        "tokens (vs fixed-width)")
    if not sweep["recompile_bound_ok"]:
        failures.append("executor/sweep: recompile keys exceed the O(log) "
                        "bound")
    # capacity sweep: stores ride the transformer scan as donated carry,
    # so KV capacity must never change emitted tokens or jit signatures
    # (both deterministic, gated exactly)
    cap = fresh["capacity_sweep"]
    cap_ok = cap["token_parity"] and cap["keys_equal"]
    print(f"  executor/capacity: parity {cap['token_parity']}  "
          f"keys_equal {cap['keys_equal']}  "
          f"[{'ok' if cap_ok else 'REGRESSION'}]")
    if not cap["token_parity"]:
        failures.append("executor/capacity: KV capacity changed emitted "
                        "tokens (must be bit-exact)")
    if not cap["keys_equal"]:
        failures.append("executor/capacity: KV capacity leaked into jit "
                        "signatures")
    if skip_wallclock:
        return
    committed = baseline["curve"]["8"]["speedup"]
    floor = max(WALLCLOCK_FLOOR, 0.25 * committed)
    got = fresh["curve"]["8"]["speedup"]
    status = "ok" if got >= floor else "REGRESSION"
    print(f"  executor/b8_speedup: committed {committed:.2f}x, fresh "
          f"fast-smoke {got:.2f}x, floor {floor:.2f}x  [{status}]")
    if status != "ok":
        failures.append(f"executor/b8_speedup {got:.2f}x below floor "
                        f"{floor:.2f}x (committed {committed:.2f}x)")
    # The fast smoke's sweep regime (1024 cap, one rung, median of a few
    # ms-scale steps) is structurally less favorable and noisier than the
    # committed full-mode run (4096 cap), so a floor derived from the
    # committed headline would flake on shared runners. A *geometry*
    # regression (bucketing silently pinned at the cap) is caught
    # deterministically by the recompile-key gates above; the wall-clock
    # check here only guards "ragged is not actively slower than fixed",
    # with jitter allowance below break-even.
    committed_s = baseline["context_sweep"]["short_context_decode_speedup"]
    floor_s = 0.8
    got_s = sweep["short_context_decode_speedup"]
    status = "ok" if got_s >= floor_s else "REGRESSION"
    print(f"  executor/short_ctx_decode_speedup: committed (full-mode) "
          f"{committed_s:.2f}x, fresh fast-smoke {got_s:.2f}x, floor "
          f"{floor_s:.2f}x  [{status}]")
    if status != "ok":
        failures.append(f"executor/short_ctx_decode_speedup {got_s:.2f}x "
                        f"below break-even floor {floor_s:.2f}x (committed "
                        f"full-mode {committed_s:.2f}x)")
    # the full-mode benchmark gates <10% flatness; the fast smoke times
    # fewer rounds on a noisy shared runner, so the floor here only has
    # to catch a return to O(capacity) step time (which measured >2x
    # spread per 4x capacity before the carry refactor)
    floor_c = 0.5
    for shape in ("decode", "prefill"):
        got_c = cap[f"{shape}_spread"]
        status = "ok" if got_c < floor_c else "REGRESSION"
        print(f"  executor/capacity_{shape}_spread: fresh fast-smoke "
              f"{got_c:.1%}, ceiling {floor_c:.0%}  [{status}]")
        if status != "ok":
            failures.append(f"executor/capacity_{shape}_spread {got_c:.1%} "
                            f"over the {floor_c:.0%} ceiling: step time "
                            "scales with KV capacity again")


def check_prefix_baseline(failures: list[str]) -> None:
    path = ROOT / "BENCH_prefix.json"
    if not path.exists():
        failures.append("BENCH_prefix.json missing - run "
                        "`python -m benchmarks.run --only prefix_cache`")
        return
    baseline = json.loads(path.read_text())
    from benchmarks.prefix_cache import measure_real_parity, measure_sim
    fresh = measure_sim()
    exact = [
        ("prefix.hits", baseline["cache"]["on"]["prefix"]["hits"],
         fresh["cache"]["on"]["prefix"]["hits"]),
        ("prefix.cow_copies",
         baseline["cache"]["on"]["prefix"]["cow_copies"],
         fresh["cache"]["on"]["prefix"]["cow_copies"]),
        ("prefix.reclassified",
         baseline["reclass_ablation"]["reclassified_requests"],
         fresh["reclass_ablation"]["reclassified_requests"]),
    ]
    for name, want, got in exact:
        status = "ok" if want == got else "REGRESSION"
        print(f"  prefix/{name}: baseline {want}  fresh {got}  [{status}]")
        if status != "ok":
            failures.append(f"prefix/{name}: {got} != baseline {want}")
    close = [
        ("prefix.token_savings", baseline["prefill_token_savings"],
         fresh["prefill_token_savings"]),
        ("prefix.ttft_mean_improvement",
         baseline["ttft_improvement"]["mean"],
         fresh["ttft_improvement"]["mean"]),
    ]
    for name, want, got in close:
        status = "ok" if _close(want, got) else "REGRESSION"
        print(f"  prefix/{name}: baseline {want:.5f}  fresh {got:.5f}  "
              f"[{status}]")
        if status != "ok":
            failures.append(f"prefix/{name}: {got:.5f} vs baseline "
                            f"{want:.5f} (tol {SIM_REL_TOL:.0%})")
    parity = measure_real_parity()["token_parity"]
    print(f"  prefix/real_token_parity: {parity}  "
          f"[{'ok' if parity else 'REGRESSION'}]")
    if not parity:
        failures.append("prefix/real_token_parity: cache on/off/legacy no "
                        "longer emit bit-identical tokens")


def check_faults_baseline(failures: list[str]) -> None:
    path = ROOT / "BENCH_faults.json"
    if not path.exists():
        failures.append("BENCH_faults.json missing - run "
                        "`python -m benchmarks.run --only fault_tolerance`")
        return
    json.loads(path.read_text())  # baseline must at least parse
    from benchmarks.fault_tolerance import measure
    fresh = measure(fast=True)
    gates = fresh["gates"]
    # every gate is exact: these are correctness invariants, not perf
    exact_zero = ["invariant_violations", "leaked_pages", "leaked_pins",
                  "in_flight", "lost", "double_finished"]
    for name in exact_zero:
        got = gates[name]
        status = "ok" if got == 0 else "REGRESSION"
        print(f"  faults/{name}: {got}  [{status}]")
        if status != "ok":
            failures.append(f"faults/{name}: {got} != 0")
    ident = gates["fault_free_identical"]
    print(f"  faults/fault_free_identical: {ident}  "
          f"[{'ok' if ident else 'REGRESSION'}]")
    if not ident:
        failures.append("faults/fault_free_identical: empty FaultPlan is "
                        "no longer a bit-exact no-op")
    if gates["redispatched"] <= 0:
        failures.append("faults/redispatched: failover path never "
                        "exercised (0 re-dispatches)")


def check_slo_baseline(failures: list[str]) -> None:
    path = ROOT / "BENCH_slo.json"
    if not path.exists():
        failures.append("BENCH_slo.json missing - run "
                        "`python -m benchmarks.run --only slo_attainment`")
        return
    json.loads(path.read_text())  # baseline must at least parse
    from benchmarks.slo_attainment import measure
    fresh = measure(fast=True)
    gates = fresh["gates"]
    exact_zero = ["invariant_violations", "leaked_pages", "leaked_pins",
                  "in_flight", "identity_rejections"]
    for name in exact_zero:
        got = gates[name]
        status = "ok" if got == 0 else "REGRESSION"
        print(f"  slo/{name}: {got}  [{status}]")
        if status != "ok":
            failures.append(f"slo/{name}: {got} != 0")
    booleans = ["plateau_ok", "off_degrades", "rejection_order_ok",
                "fairness_ok", "identity_ok"]
    for name in booleans:
        got = gates[name]
        status = "ok" if got else "REGRESSION"
        print(f"  slo/{name}: {got}  [{status}]")
        if status != "ok":
            failures.append(f"slo/{name} gate failed")
    if gates["bucket_min_level"] < 0:
        failures.append(f"slo/bucket_min_level: "
                        f"{gates['bucket_min_level']} < 0 — a tenant "
                        "token bucket went negative")
    if gates["chaos_rejected"] <= 0 or gates["chaos_faulted"] <= 0:
        failures.append("slo/chaos composition never exercised admission "
                        "and faults together")


def check_fleet_baseline(failures: list[str]) -> None:
    path = ROOT / "BENCH_fleet.json"
    if not path.exists():
        failures.append("BENCH_fleet.json missing - run "
                        "`python -m benchmarks.run --only fleet_tolerance`")
        return
    json.loads(path.read_text())  # baseline must at least parse
    from benchmarks.fleet_tolerance import measure
    fresh = measure(fast=True)
    gates = fresh["gates"]
    exact_zero = ["invariant_violations", "leaked_pages", "leaked_pins",
                  "in_flight", "lost", "double_finished"]
    for name in exact_zero:
        got = gates[name]
        status = "ok" if got == 0 else "REGRESSION"
        print(f"  fleet/{name}: {got}  [{status}]")
        if status != "ok":
            failures.append(f"fleet/{name}: {got} != 0")
    booleans = ["real_migration_parity", "elastic_beats_static",
                "no_events_identical"]
    for name in booleans:
        got = gates[name]
        status = "ok" if got else "REGRESSION"
        print(f"  fleet/{name}: {got}  [{status}]")
        if status != "ok":
            failures.append(f"fleet/{name} gate failed")
    if gates["migrations_succeeded"] <= 0 or gates["pages_transferred"] <= 0:
        failures.append("fleet/migration path never delivered a chain")
    if gates["drains_completed"] != gates["drains_scheduled"]:
        failures.append(f"fleet/drains: {gates['drains_completed']} of "
                        f"{gates['drains_scheduled']} scheduled drains "
                        "completed")
    if gates["repartitions"] <= 0:
        failures.append("fleet/repartitions: mix shift never repartitioned")


def check_recovery_baseline(failures: list[str]) -> None:
    path = ROOT / "BENCH_recovery.json"
    if not path.exists():
        failures.append("BENCH_recovery.json missing - run "
                        "`python -m benchmarks.run --only recovery`")
        return
    json.loads(path.read_text())  # baseline must at least parse
    from benchmarks.recovery import measure
    fresh = measure(fast=True)
    gates = fresh["gates"]
    exact_zero = ["invariant_violations", "leaked_pages", "leaked_pins",
                  "in_flight", "lost", "double_finished",
                  "journal_mismatches"]
    for name in exact_zero:
        got = gates[name]
        status = "ok" if got == 0 else "REGRESSION"
        print(f"  recovery/{name}: {got}  [{status}]")
        if status != "ok":
            failures.append(f"recovery/{name}: {got} != 0")
    if gates["journal_checks"] <= 0:
        failures.append("recovery/journal_checks: no replay cross-check "
                        "ever ran")
    if gates["rejoin_events"] != gates["restarts_fired"] or \
            gates["restarts_fired"] < 3:
        failures.append(
            f"recovery/restarts: {gates['rejoin_events']} rejoins of "
            f"{gates['restarts_fired']} fired")
    if gates["post_restart_finished"] <= 0:
        failures.append("recovery/post_restart: no restarted engine did "
                        "fresh work")
    if not gates["journal_identity"]:
        failures.append("recovery/journal_identity: journal-enabled "
                        "event-free run diverged from Router")
    if gates["recovery_ratio"] < 0.5:
        failures.append(f"recovery/goodput ratio "
                        f"{gates['recovery_ratio']:.2f} < 0.5")


def main(argv: list[str]) -> int:
    failures: list[str] = []
    print("== perf regression gate ==")
    check_encode_baseline(failures)
    check_prefix_baseline(failures)
    check_faults_baseline(failures)
    check_fleet_baseline(failures)
    check_recovery_baseline(failures)
    check_slo_baseline(failures)
    check_executor_baseline(failures,
                            skip_wallclock="--skip-wallclock" in argv)
    if "--skip-wallclock" not in argv:
        check_scheduler_baseline(failures)
    if failures:
        print("\nREGRESSIONS DETECTED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nno perf regressions vs committed baselines")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
