"""Paper Fig. 12: increasing request rate; overall norm latency, avg TTFT,
P90 TTFT. TCM must degrade most gracefully."""
from .common import csv_row, pctl, run_policy


def main(fast: bool = False):
    rows = []
    n = 120 if fast else 250
    rates = [1.0, 2.0, 3.0] if fast else [1.0, 1.5, 2.0, 2.5, 3.0]
    print("rate,policy,overall_norm_lat,ttft_avg,ttft_p90")
    for rate in rates:
        for pol in ["fcfs", "edf", "tcm"]:
            s, done, _ = run_policy(pol, rate=rate, n=n)
            p90 = pctl([r.ttft() for r in done], 90)
            print(f"{rate},{pol},{s['overall']['norm_latency_avg']:.4f},"
                  f"{s['overall']['ttft_avg']:.3f},{p90:.3f}")
            if pol == "tcm":
                rows.append(csv_row(f"fig12_rate{rate}_tcm_ttft_p90", p90))
    return rows


if __name__ == "__main__":
    main()
