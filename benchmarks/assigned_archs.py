"""TCM vs FCFS on ALL 10 assigned architectures (deliverable f x paper
technique): cost models derived from each arch's real dimensions
(`cost_model_for_arch`), request rate scaled to model capacity.

For text-only backbones the multimodal "trucks" degrade to very long
prompts — the resource-aware classifier handles them identically (the
paper's own argument for smart over naive classification). See DESIGN.md
§Arch-applicability.
"""
from repro.configs import ALIASES, get_config
from repro.core.classifier import SmartClassifier
from repro.core.estimator import ImpactEstimator
from repro.core.profiler import WorkloadProfiler
from repro.core.scheduler import make_policy
from repro.serving.engine import Engine, EngineConfig
from repro.serving.executors import SimExecutor, cost_model_for_arch
from repro.serving.metrics import summarize
from repro.serving.workload import WorkloadConfig, generate, \
    profiling_workload

from .common import csv_row


def main(fast: bool = False):
    rows = []
    n = 120 if fast else 200
    archs = list(ALIASES) if not fast else list(ALIASES)[:4]
    print("arch,policy,M_ttft,O_ttft,O_viol,reduction_overall")
    for arch in archs:
        cfg = get_config(arch)
        cm = cost_model_for_arch(cfg)
        ex = SimExecutor(cm)
        profile = WorkloadProfiler(ex, arch).build(
            profiling_workload(n_per_modality=60))
        est = ImpactEstimator.train(profile)
        smart = SmartClassifier.train(est, profile)
        # load scaled to capacity: ~2 rps for a 7B-class model
        rate = max(0.05, min(8.0, 2.0 * 7e9 / cm.n_params))
        out = {}
        for pol in ["fcfs", "tcm"]:
            eng = Engine(make_policy(pol), ex, smart,
                         EngineConfig(token_budget=512))
            reqs = generate(WorkloadConfig(
                mix="MH", rate=rate, num_requests=n, seed=7,
                video_frames_max=96))
            out[pol] = summarize(eng.run(reqs))
        f, t = out["fcfs"], out["tcm"]
        red = 1 - t["overall"]["ttft_avg"] / max(f["overall"]["ttft_avg"], 1e-9)
        for pol in ["fcfs", "tcm"]:
            s = out[pol]
            print(f"{arch},{pol},{s['motorcycle']['ttft_avg']:.3f},"
                  f"{s['overall']['ttft_avg']:.3f},"
                  f"{s['overall']['slo_violation_rate']:.3f},"
                  f"{red if pol == 'tcm' else 0:.3f}")
        rows.append(csv_row(f"assigned_{arch}_ttft_reduction", red,
                            f"rate={rate:.2f}"))
        # the paper's O1 on every assigned architecture: latency-critical
        # requests must get dramatically faster (overall mean may regress
        # under saturation, where TCM deliberately sacrifices trucks)
        assert t["motorcycle"]["ttft_avg"] < \
            0.5 * f["motorcycle"]["ttft_avg"], arch
    return rows


if __name__ == "__main__":
    main()
