"""Paper Fig. 13: TCM under T0 / ML / MH. TCM must excel on text-only too
(motorcycle TTFT ~0.05-0.15s, violations < a few %)."""
from .common import csv_row, run_policy


def main(fast: bool = False):
    rows = []
    n = 150 if fast else 300
    print("mix,class,ttft_avg,viol_rate,severity")
    for mix in ["T0", "ML", "MH"]:
        s, _, _ = run_policy("tcm", mix=mix, n=n)
        for g in ["motorcycle", "car", "truck", "overall"]:
            if s[g] is None:
                continue
            print(f"{mix},{g},{s[g]['ttft_avg']:.3f},"
                  f"{s[g]['slo_violation_rate']:.3f},"
                  f"{s[g]['violation_severity_avg']:.2f}")
        rows.append(csv_row(f"fig13_{mix}_moto_ttft",
                            s["motorcycle"]["ttft_avg"]))
    return rows


if __name__ == "__main__":
    main()
