"""Paper Fig. 11: preemption counts + aggregate preempted time per class.
TCM must show ZERO motorcycle preemptions."""
from .common import csv_row, run_policy


def main(fast: bool = False):
    rows = []
    n = 150 if fast else 300
    # tighter memory to induce preemption pressure
    print("policy,class,preemptions,preempted_time_s")
    for pol in ["fcfs", "edf", "tcm"]:
        s, _, _ = run_policy(pol, n=n, kv_pages=6144)
        for g in ["motorcycle", "car", "truck", "overall"]:
            print(f"{pol},{g},{s[g]['preemptions']},{s[g]['preempted_time']:.1f}")
        rows.append(csv_row(f"fig11_{pol}_moto_preemptions",
                            s["motorcycle"]["preemptions"]))
        if pol == "tcm":
            assert s["motorcycle"]["preemptions"] == 0, \
                "TCM must never preempt motorcycles"
    return rows


if __name__ == "__main__":
    main()
