"""Paper Fig. 3: FCFS (vLLM) degradation as multimodal intensity grows
(T0 -> ML -> MH). Text requests suffer most."""
from .common import csv_row, run_policy


def main(fast: bool = False):
    rows = []
    n = 150 if fast else 300
    print("mix,class,ttft_avg,norm_lat,viol_rate,severity")
    for mix in ["T0", "ML", "MH"]:
        s, _, _ = run_policy("fcfs", mix=mix, n=n)
        for g in ["motorcycle", "car", "truck", "overall"]:
            if s[g] is None:
                continue
            print(f"{mix},{g},{s[g]['ttft_avg']:.3f},{s[g]['norm_latency_avg']:.4f},"
                  f"{s[g]['slo_violation_rate']:.3f},{s[g]['violation_severity_avg']:.2f}")
        rows.append(csv_row(f"fig3_{mix}_overall_ttft", s["overall"]["ttft_avg"],
                            f"viol={s['overall']['slo_violation_rate']:.2f}"))
    return rows


if __name__ == "__main__":
    main()
