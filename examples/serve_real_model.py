"""End-to-end driver: serve a REAL (reduced) JAX model with batched
multimodal requests through the TCM engine on CPU.

Every token is actually computed — the batched paged-KV execution path
runs each engine iteration as one packed prefill call plus one fused
decode step over the whole running set (block tables from the engine
allocator, greedy tokens fed back); engine timing comes from measured
wall-clock. Pass executor_kind="real-legacy" for the sequential
per-request oracle the batched path is benchmarked against.

  PYTHONPATH=src python examples/serve_real_model.py
"""
from repro.launch.serve import serve
from repro.serving.metrics import fmt_table, summarize
from repro.serving.workload import WorkloadConfig

wl = WorkloadConfig(
    mix="MH", rate=20.0, num_requests=12, seed=3,
    # shrink sizes so the reduced model's 256-token window fits
    text_tokens_log_mu=3.0, text_tokens_log_sigma=0.5,
    image_patches=48, video_frames_min=2, video_frames_max=4,
    video_patches_per_frame=16,
    out_tokens_log_mu=2.0, out_tokens_log_sigma=0.3)

done, engine = serve("qwen2-vl-2b", "tcm", wl, executor_kind="real")
print(fmt_table(summarize(done), "real JAX model (reduced qwen2-vl), TCM"))
print(f"iterations={engine.iterations}  wall(sim)={engine.now:.2f}s  "
      f"completed={len(done)}/12")
