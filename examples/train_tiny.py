"""End-to-end training driver: train the ~100M xLSTM (an assigned arch!) on
the synthetic packed-token pipeline for a few hundred steps on CPU, with
checkpoint save + resume.

  PYTHONPATH=src python examples/train_tiny.py [--steps 200]
"""
import sys

sys.argv = [sys.argv[0], "--arch", "xlstm-125m",
            "--steps", "200", "--batch", "4", "--seq", "64",
            "--ckpt", "experiments/xlstm_125m.npz", "--log-every", "20"] \
    + sys.argv[1:]
from repro.launch.train import main

main()
