"""Quickstart: the full TCM-Serve pipeline in ~40 lines.

Profiles a model, trains the Impact Estimator + smart classifier, runs the
engine under a heavy multimodal mix with the TCM policy vs vLLM-style FCFS,
and prints the paper's headline comparison.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.scheduler import make_policy
from repro.launch.serve import build_stack
from repro.serving.engine import Engine
from repro.serving.metrics import fmt_table, summarize
from repro.serving.workload import WorkloadConfig, generate

# 1. profile the model + train estimator/classifier (paper §3.2-3.4)
executor, classifier, engine_cfg, profile, estimator = build_stack(
    "qwen2-vl-2b", "sim", model_preset="llava-7b")

# show what the classifier learned
for mod, text, mm in [("text", 120, 0), ("text", 9000, 0),
                      ("image", 40, 576), ("video", 40, 196 * 64)]:
    vclass, est_s, est_kv = classifier.classify(mod, text, mm)
    print(f"{mod:6s} text={text:5d} mm={mm:6d} -> {vclass.value:11s} "
          f"(est prefill {est_s*1e3:7.1f} ms, est KV {est_kv:8.0f} tok)")

# 2. serve a heavy multimodal mix with TCM vs FCFS (paper Fig. 10)
wl = WorkloadConfig(mix="MH", rate=2.0, num_requests=200, seed=7,
                    video_frames_max=96)
results = {}
for policy in ["fcfs", "tcm"]:
    engine = Engine(make_policy(policy), executor, classifier, engine_cfg)
    done = engine.run(generate(wl))
    results[policy] = summarize(done)
    print()
    print(fmt_table(results[policy], f"policy={policy}"))

f, t = results["fcfs"], results["tcm"]
print(f"\nTTFT reduction: overall "
      f"{1 - t['overall']['ttft_avg']/f['overall']['ttft_avg']:.0%} "
      f"(paper: 54%), latency-critical "
      f"{1 - t['motorcycle']['ttft_avg']/f['motorcycle']['ttft_avg']:.0%} "
      f"(paper: 78.5%)")
