"""Lower + compile ONE (arch x shape) combination on the 512-chip
multi-pod production mesh and print its roofline terms.

  PYTHONPATH=src python examples/dryrun_one.py [arch] [shape]
"""
import sys

from repro.launch.dryrun import run_one

arch = sys.argv[1] if len(sys.argv) > 1 else "gemma3-27b"
shape = sys.argv[2] if len(sys.argv) > 2 else "decode_32k"
result = run_one(arch, shape, "multi", "experiments/dryrun")
print("\nroofline:", result["roofline"])
